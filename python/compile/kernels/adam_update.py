"""Fused Adam update as a Pallas kernel (paper eq. 3-5).

The paper's local update rule on device *n*, epoch *l*:

    m <- beta1 * m + (1 - beta1) * g            (eq. 4)
    v <- beta2 * v + (1 - beta2) * g^2          (eq. 5)
    w <- w - eta * m / sqrt(v + eps)            (eq. 3)

Note the paper places ``eps`` *inside* the square root (eq. 3) and applies
no bias correction; we follow the paper exactly and the pure-jnp oracle in
:mod:`compile.kernels.ref` encodes the same rule.

Hardware adaptation (DESIGN.md §Hardware-Adaptation): the update is
element-wise and bandwidth-bound, so the kernel is a single fused pass over
1-D blocks of the flat parameter vector.  ``BLOCK`` is sized so that the six
resident operand blocks (w, m, v, g in; three outs) fit comfortably in a TPU
core's ~16 MiB VMEM while staying a multiple of the 8x128 VPU tile.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# 64 Ki f32 per block = 256 KiB; 7 resident blocks ~ 1.75 MiB << VMEM.
BLOCK = 64 * 1024


def _adam_kernel(w_ref, m_ref, v_ref, g_ref, h_ref, wo_ref, mo_ref, vo_ref):
    """One fused pass: new moments then parameter step.

    h_ref holds the scalar hyperparameters broadcast to block shape is
    avoided; instead they arrive as a tiny (4,) vector in SMEM-like layout:
    [eta, beta1, beta2, eps].
    """
    eta = h_ref[0]
    beta1 = h_ref[1]
    beta2 = h_ref[2]
    eps = h_ref[3]
    g = g_ref[...]
    m = beta1 * m_ref[...] + (1.0 - beta1) * g
    v = beta2 * v_ref[...] + (1.0 - beta2) * g * g
    mo_ref[...] = m
    vo_ref[...] = v
    wo_ref[...] = w_ref[...] - eta * m / jnp.sqrt(v + eps)


@functools.partial(jax.jit, static_argnames=("block",))
def adam_update(w, m, v, g, eta, beta1=0.9, beta2=0.999, eps=1e-6, *, block=BLOCK):
    """Fused Adam step over flat f32 vectors.

    Args:
      w, m, v, g: ``f32[d]`` parameter vector, first/second moment, gradient.
      eta: learning rate (scalar, may be traced — the lr sweep of paper
        Fig. 4 runs without recompilation).
      beta1, beta2, eps: Adam constants (paper defaults 0.9 / 0.999 / 1e-6).
      block: Pallas block size along the flat axis.

    Returns:
      ``(w', m', v')`` with the paper's update rule applied element-wise.
    """
    d = w.shape[0]
    # Pad to a block multiple so the grid is rectangular; padded lanes are
    # sliced off below (their v-update divides by sqrt(eps) but never leaks).
    dpad = (d + block - 1) // block * block
    pad = dpad - d

    def padf(x):
        return jnp.pad(x, (0, pad)) if pad else x

    hyper = jnp.stack(
        [
            jnp.asarray(eta, jnp.float32),
            jnp.asarray(beta1, jnp.float32),
            jnp.asarray(beta2, jnp.float32),
            jnp.asarray(eps, jnp.float32),
        ]
    )
    grid = dpad // block
    out_shape = [jax.ShapeDtypeStruct((dpad,), jnp.float32)] * 3
    spec = pl.BlockSpec((block,), lambda i: (i,))
    hspec = pl.BlockSpec((4,), lambda i: (0,))
    wn, mn, vn = pl.pallas_call(
        _adam_kernel,
        grid=(grid,),
        in_specs=[spec, spec, spec, spec, hspec],
        out_specs=[spec, spec, spec],
        out_shape=out_shape,
        interpret=True,
    )(padf(w), padf(m), padf(v), padf(g), hyper)
    if pad:
        wn, mn, vn = wn[:d], mn[:d], vn[:d]
    return wn, mn, vn
