//! FedAdam-Top (paper §IV): each of (ΔW, ΔM, ΔV) gets its OWN top-k mask.
//!
//! The lowest-sparsification-error sparse FedAdam (Remark 2) — but it pays
//! three masks on the wire (`min{3(kq+d), 3k(q+log₂d)}`) and 3× the
//! selection compute (`O(3d log k)` vs the SSM's `O(d log k)`).

use super::{Aggregate, Algorithm, LocalDelta, Recon, Upload};
use crate::sparse::codec::cost;
use crate::sparse::{top_k_indices, SparseVec};

pub struct FedAdamTop {
    dim: usize,
    k: usize,
}

impl FedAdamTop {
    pub fn new(dim: usize, k: usize) -> Self {
        assert!(k >= 1 && k <= dim);
        FedAdamTop { dim, k }
    }
}

impl Algorithm for FedAdamTop {
    fn name(&self) -> &'static str {
        "fedadam-top"
    }

    fn compress(&mut self, _round: usize, _device: usize, delta: LocalDelta) -> Upload {
        let iw = top_k_indices(&delta.dw, self.k);
        let im = top_k_indices(&delta.dm, self.k);
        let iv = top_k_indices(&delta.dv, self.k);
        Upload {
            dw: Recon::Sparse(SparseVec::gather(&delta.dw, &iw)),
            dm: Some(Recon::Sparse(SparseVec::gather(&delta.dm, &im))),
            dv: Some(Recon::Sparse(SparseVec::gather(&delta.dv, &iv))),
            weight: delta.weight,
            bits: cost::fedadam_top(self.dim, self.k),
        }
    }

    fn downlink_bits(&self, agg: &Aggregate) -> u64 {
        // Three independent sparse broadcasts, each priced from the union
        // support carried through `Aggregate` (recounting non-zeros of the
        // sums undercounts on exact-zero cancellation).
        use crate::sparse::codec::{mask_bits, Q};
        let one = |k: usize| mask_bits(self.dim, k).0 + k as u64 * Q;
        one(agg.dw_support) + one(agg.dm_support) + one(agg.dv_support)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn three_independent_masks() {
        let mut a = FedAdamTop::new(8, 2);
        let delta = LocalDelta {
            dw: vec![9.0, 0.0, 0.0, 0.0, 0.0, 0.0, 0.0, 8.0],
            dm: vec![0.0, 9.0, 8.0, 0.0, 0.0, 0.0, 0.0, 0.0],
            dv: vec![0.0, 0.0, 0.0, 9.0, 8.0, 0.0, 0.0, 0.0],
            weight: 1.0,
        };
        let up = a.compress(0, 0, delta);
        let idx = |r: &Recon| match r {
            Recon::Sparse(sv) => sv.indices.clone(),
            _ => panic!(),
        };
        assert_eq!(idx(&up.dw), vec![0, 7]);
        assert_eq!(idx(up.dm.as_ref().unwrap()), vec![1, 2]);
        assert_eq!(idx(up.dv.as_ref().unwrap()), vec![3, 4]);
        assert_eq!(up.bits, cost::fedadam_top(8, 2));
    }

    #[test]
    fn costs_more_than_ssm() {
        assert!(cost::fedadam_top(50_000, 2_500) > cost::fedadam_ssm(50_000, 2_500));
    }
}
