//! Top-k selection microbench (the SSM hot path, DESIGN.md §Perf L3).
//!
//! Compares quickselect (`sparse::topk`) against a full sort baseline at
//! the paper's α = 0.05 across model dimensions, plus α scaling at fixed d.
//!
//! Run: `cargo bench --bench topk` (env `FEDADAM_BENCH_QUICK=1` for CI).

use fedadam_ssm::benchlib::{black_box, from_env};
use fedadam_ssm::rng::Rng;
use fedadam_ssm::sparse::top_k_indices;

fn sort_baseline(x: &[f32], k: usize) -> Vec<u32> {
    let mut idx: Vec<u32> = (0..x.len() as u32).collect();
    idx.sort_by(|&a, &b| {
        x[b as usize]
            .abs()
            .partial_cmp(&x[a as usize].abs())
            .unwrap()
            .then(a.cmp(&b))
    });
    let mut out: Vec<u32> = idx[..k].to_vec();
    out.sort_unstable();
    out
}

fn main() {
    let mut bench = from_env();
    let mut rng = Rng::new(42);

    // d sweep at alpha = 0.05 (paper default): the three model scales.
    for &d in &[54_314usize, 176_778, 1_663_370] {
        let x: Vec<f32> = (0..d).map(|_| rng.normal() as f32).collect();
        let k = d / 20;
        bench.run(format!("quickselect d={d} k={k}"), || {
            black_box(top_k_indices(&x, k));
        });
        bench.run(format!("sort-baseline d={d} k={k}"), || {
            black_box(sort_baseline(&x, k));
        });
    }

    // alpha sweep at cnn_small's d.
    let d = 54_314;
    let x: Vec<f32> = (0..d).map(|_| rng.normal() as f32).collect();
    for &alpha in &[0.01f64, 0.05, 0.2, 0.5] {
        let k = ((d as f64 * alpha) as usize).max(1);
        bench.run(format!("quickselect d={d} alpha={alpha}"), || {
            black_box(top_k_indices(&x, k));
        });
    }

    bench.report("top-k selection");
    println!("\n{}", bench.to_csv());
}
