"""Top-k selection utilities (the SSM selection rule, paper eq. 6-7, 28).

The shared sparse mask of FedAdam-SSM is the top-k mask of ``|dW|``
(eq. 28).  Selection splits into two parts:

1. :func:`topk_threshold` — find ``tau``, the k-th largest ``|x|``.  This is
   a global reduction; we express it with a full sort (XLA's sort is a
   bitonic network on TPU) followed by a dynamic slice so that **k can be a
   runtime scalar** — the sparsification-ratio sweep of paper Fig. 5 runs
   against a single compiled artifact.
2. :func:`topk_mask` — the embarrassingly-parallel compare against ``tau``,
   written as a Pallas kernel (it fuses with the mask-apply pass in
   :mod:`compile.kernels.ssm_sparsify`).

Tie handling: elements equal to ``tau`` are all kept, so the mask can hold
slightly more than ``k`` ones when ``|x|`` has duplicates.  The rust L3
implementation (``sparse::topk``) breaks ties by index to give exactly-k
masks; the cross-layer tests treat masks as equivalent when the kept value
*sets* agree on non-tied inputs.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from compile.kernels.adam_update import BLOCK


@jax.jit
def topk_threshold(x, k):
    """Return ``tau`` = k-th largest value of ``|x|`` (runtime ``k``).

    Args:
      x: ``f32[d]``.
      k: scalar int32 in ``[1, d]``; may be traced.  ``k`` is clipped into
        ``[1, d]`` — this kernel cannot represent an empty selection.  The
        rust runtime (``sparse::topk::top_k_threshold``) extends the same
        ``|x| >= tau`` keep rule to ``k == 0`` / empty input by returning
        ``+inf`` (nothing passes); callers that need ``k == 0`` must handle
        it host-side, never here.

    Returns:
      Scalar f32 threshold such that ``|x| >= tau`` keeps the top-k
      (ties included).
    """
    mag = jnp.abs(x)
    sorted_desc = jnp.sort(mag)[::-1]
    k = jnp.clip(jnp.asarray(k, jnp.int32), 1, x.shape[0])
    return jax.lax.dynamic_index_in_dim(sorted_desc, k - 1, keepdims=False)


def _mask_kernel(x_ref, t_ref, o_ref):
    o_ref[...] = (jnp.abs(x_ref[...]) >= t_ref[0]).astype(jnp.float32)


@functools.partial(jax.jit, static_argnames=("block",))
def topk_mask(x, k, *, block=BLOCK):
    """Binary f32 mask of the top-k elements of ``|x|`` (ties kept).

    The threshold is computed once (sort) and the compare runs as a blocked
    Pallas pass.
    """
    d = x.shape[0]
    tau = topk_threshold(x, k)
    dpad = (d + block - 1) // block * block
    pad = dpad - d
    xp = jnp.pad(x, (0, pad)) if pad else x
    spec = pl.BlockSpec((block,), lambda i: (i,))
    tspec = pl.BlockSpec((1,), lambda i: (0,))
    mask = pl.pallas_call(
        _mask_kernel,
        grid=(dpad // block,),
        in_specs=[spec, tspec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((dpad,), jnp.float32),
        interpret=True,
    )(xp, tau[None])
    if pad:
        mask = mask[:d]
    # Padded lanes are zero (|0| >= tau only if tau == 0; guard below).
    # When tau == 0 every real element is kept anyway, so zeroing the pad
    # region keeps the mask semantics intact.
    return mask
