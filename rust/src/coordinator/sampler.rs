//! Pluggable partial-participation device sampling.
//!
//! Every round the coordinator asks its [`ParticipationSampler`] for a
//! [`Cohort`]: *which* devices train, and *what FedAvg weight* each one's
//! upload carries through the aggregation path.  Three deterministic,
//! seed-driven implementations sit behind the `participation_mode` knob:
//!
//! - [`UniformSampler`] (`uniform`, default) — uniform without
//!   replacement, **bit-identical to the original loop**: the same RNG
//!   stream (`seed ^ 0x5a3c_91f7`), the same shuffle/truncate/sort, and
//!   cohort weights equal to the devices' data sizes.
//! - [`ImportanceSampler`] (`importance`) — `m` i.i.d. draws with
//!   probability `p_i ∝ |D_i|` (local data size).  Each unique selected
//!   device carries weight `mult_i · |D_i| / (m·p_i)`, the classical
//!   unbiased importance re-weighting: the cohort's weighted FedAvg
//!   aggregate has the full-participation aggregate as its expectation,
//!   and the cohort weights always sum to the full corpus weight, so the
//!   downstream `weight / Σweights` normalization *is* the `1/(m·p_i)`
//!   estimator.
//! - [`AvailabilitySampler`] (`availability`) — each device follows a
//!   deterministic per-round on/off duty-cycle trace (a pure function of
//!   `(seed, device, round)`).  The sampler over-selects up to
//!   `ceil(target · over_select)` available candidates, then enforces the
//!   round deadline by keeping the `target` fastest (by simulated compute
//!   latency, ties by id) and dropping the over-selected stragglers.  A
//!   floor of one device is always enforced — an all-off round falls back
//!   to a deterministic single device.
//!
//! All three are pure functions of `(config, data sizes, latencies,
//! round)` — no host entropy, no wall clock — so cohorts are identical at
//! any `num_workers` / `agg_shards` / `pipeline_depth`.
//!
//! ```
//! use fedadam_ssm::config::{ExperimentConfig, ParticipationMode};
//! use fedadam_ssm::coordinator::sampler::{self, ParticipationSampler as _};
//!
//! let mut cfg = ExperimentConfig::default();
//! cfg.participation = 0.5;
//! cfg.participation_mode = ParticipationMode::Importance;
//! let data = [60.0, 30.0, 10.0, 20.0];
//! let latency = [0.0; 4];
//! let mut a = sampler::build(&cfg, &data, &latency);
//! let mut b = sampler::build(&cfg, &data, &latency);
//! // Seed-deterministic: an identically-built sampler replays the cohort.
//! let cohort = a.sample(0);
//! assert_eq!(cohort.devices, b.sample(0).devices);
//! assert!(!cohort.devices.is_empty());
//! ```

use anyhow::Result;

use crate::config::{ExperimentConfig, ParticipationMode};
use crate::rng::Rng;
use crate::util::bytes::{ByteReader, ByteWriter};

/// The legacy participation stream tag (pre-sampler coordinator seeded its
/// shuffle RNG with `seed ^ 0x5a3c_91f7`) — [`UniformSampler`] must keep
/// it to stay bit-identical.
const UNIFORM_STREAM: u64 = 0x5a3c_91f7;
/// Importance-draw stream tag (domain-separated from every other seed use).
const IMPORTANCE_STREAM: u64 = 0x7e2d_9b14_55c3_a86f;
/// Availability duty-cycle trace tag.
const TRACE_STREAM: u64 = 0x3f91_44d0_8ae7_125b;
/// Availability per-round candidate-shuffle tag.
const SELECT_STREAM: u64 = 0xc65a_07e9_31fd_b842;

/// One round's participants: device ids (ascending, unique) and the
/// FedAvg weight each upload carries (same order).
#[derive(Clone, Debug, PartialEq)]
pub struct Cohort {
    /// Participating device ids, strictly ascending.
    pub devices: Vec<usize>,
    /// Effective FedAvg weight per participant (aligned with `devices`).
    pub weights: Vec<f64>,
}

impl Cohort {
    /// Number of participating devices.
    pub fn len(&self) -> usize {
        self.devices.len()
    }

    /// `true` when no device participates (samplers never produce this —
    /// a floor of one device is enforced everywhere).
    pub fn is_empty(&self) -> bool {
        self.devices.is_empty()
    }

    /// Sum of the cohort's FedAvg weights.
    pub fn total_weight(&self) -> f64 {
        self.weights.iter().sum()
    }
}

/// Per-round cohort selection strategy — one instance per experiment.
pub trait ParticipationSampler: Send {
    /// Stable id (matches `ParticipationMode::as_str`).
    fn name(&self) -> &'static str;

    /// The cohort for communication round `round`.  Must be deterministic
    /// given the constructor inputs and `round`, return strictly
    /// ascending unique device ids, and never be empty.
    fn sample(&mut self, round: usize) -> Cohort;

    /// Serialize the sampler's advancing cursor (RNG stream position) into
    /// a journal snapshot.  Stateless samplers (pure functions of `round`)
    /// write nothing.
    fn save_state(&self, out: &mut ByteWriter) {
        let _ = out;
    }

    /// Restore the cursor written by [`Self::save_state`].
    fn load_state(&mut self, input: &mut ByteReader) -> Result<()> {
        let _ = input;
        Ok(())
    }
}

/// Target cohort size: `round(n · participation)` clamped to `[1, n]` —
/// the exact formula of the original loop.
pub fn target_cohort_size(devices: usize, participation: f64) -> usize {
    ((devices as f64 * participation).round() as usize).clamp(1, devices)
}

/// Build the sampler the config asks for.  `data_weights[i]` is device
/// `i`'s FedAvg data weight (`|D_i|`); `compute_secs[i]` its simulated
/// per-round compute latency (the availability deadline ranking).
pub fn build(
    cfg: &ExperimentConfig,
    data_weights: &[f64],
    compute_secs: &[f64],
) -> Box<dyn ParticipationSampler> {
    assert_eq!(
        data_weights.len(),
        compute_secs.len(),
        "one latency per device"
    );
    match cfg.participation_mode {
        ParticipationMode::Uniform => Box::new(UniformSampler::new(
            cfg.seed,
            cfg.participation,
            data_weights.to_vec(),
        )),
        ParticipationMode::Importance => Box::new(ImportanceSampler::new(
            cfg.seed,
            cfg.participation,
            data_weights.to_vec(),
        )),
        ParticipationMode::Availability => Box::new(AvailabilitySampler::new(
            cfg.seed,
            cfg.participation,
            cfg.duty_cycle,
            cfg.over_select,
            data_weights.to_vec(),
            compute_secs.to_vec(),
        )),
    }
}

/// Uniform without replacement — the original loop, verbatim.
pub struct UniformSampler {
    rng: Rng,
    participation: f64,
    data_weights: Vec<f64>,
}

impl UniformSampler {
    pub fn new(seed: u64, participation: f64, data_weights: Vec<f64>) -> UniformSampler {
        UniformSampler {
            // The legacy stream: MUST stay `seed ^ 0x5a3c_91f7` (and be
            // consumed only on m < n rounds) for bit-identity with the
            // pre-sampler coordinator.
            rng: Rng::new(seed ^ UNIFORM_STREAM),
            participation,
            data_weights,
        }
    }
}

impl ParticipationSampler for UniformSampler {
    fn name(&self) -> &'static str {
        "uniform"
    }

    fn sample(&mut self, _round: usize) -> Cohort {
        let n = self.data_weights.len();
        let m = target_cohort_size(n, self.participation);
        let devices: Vec<usize> = if m == n {
            // Full participation consumes no randomness (legacy contract).
            (0..n).collect()
        } else {
            let mut idx: Vec<usize> = (0..n).collect();
            self.rng.shuffle(&mut idx);
            idx.truncate(m);
            idx.sort_unstable();
            idx
        };
        let weights = devices.iter().map(|&i| self.data_weights[i]).collect();
        Cohort { devices, weights }
    }

    fn save_state(&self, out: &mut ByteWriter) {
        out.put_u64s(&self.rng.state());
    }

    fn load_state(&mut self, input: &mut ByteReader) -> Result<()> {
        let s = input.take_u64s()?;
        anyhow::ensure!(s.len() == 4, "sampler cursor must be 4 words");
        self.rng = Rng::from_state([s[0], s[1], s[2], s[3]]);
        Ok(())
    }
}

/// Data-size-proportional sampling with unbiased re-weighting.
pub struct ImportanceSampler {
    rng: Rng,
    participation: f64,
    data_weights: Vec<f64>,
    /// `Σ |D_i|` over the whole fleet.
    total: f64,
}

impl ImportanceSampler {
    pub fn new(seed: u64, participation: f64, data_weights: Vec<f64>) -> ImportanceSampler {
        let total: f64 = data_weights.iter().sum();
        assert!(
            total > 0.0 && data_weights.iter().all(|&w| w > 0.0),
            "importance sampling needs strictly positive data weights"
        );
        ImportanceSampler {
            rng: Rng::new(seed ^ IMPORTANCE_STREAM),
            participation,
            data_weights,
            total,
        }
    }

    /// Selection probability of device `i` in one draw.
    pub fn prob(&self, i: usize) -> f64 {
        self.data_weights[i] / self.total
    }
}

impl ParticipationSampler for ImportanceSampler {
    fn name(&self) -> &'static str {
        "importance"
    }

    fn sample(&mut self, _round: usize) -> Cohort {
        let n = self.data_weights.len();
        let m = target_cohort_size(n, self.participation);
        // m i.i.d. draws with replacement, p_i ∝ |D_i|; a device drawn
        // `mult` times trains once and its upload carries `mult` shares.
        let mut mult = vec![0usize; n];
        for _ in 0..m {
            mult[self.rng.categorical(&self.data_weights)] += 1;
        }
        let mut devices = Vec::new();
        let mut weights = Vec::new();
        for (i, &c) in mult.iter().enumerate() {
            if c > 0 {
                devices.push(i);
                // Unbiased estimator share: mult · w_i / (m·p_i).  With
                // p_i ∝ w_i each share is total/m, so the cohort weights
                // sum to the FULL corpus weight and the aggregate's
                // `weight/Σweights` normalization equals the 1/(m·p_i)
                // re-weighted FedAvg estimator exactly.
                let p = self.prob(i);
                weights.push(c as f64 * self.data_weights[i] / (m as f64 * p));
            }
        }
        Cohort { devices, weights }
    }

    fn save_state(&self, out: &mut ByteWriter) {
        out.put_u64s(&self.rng.state());
    }

    fn load_state(&mut self, input: &mut ByteReader) -> Result<()> {
        let s = input.take_u64s()?;
        anyhow::ensure!(s.len() == 4, "sampler cursor must be 4 words");
        self.rng = Rng::from_state([s[0], s[1], s[2], s[3]]);
        Ok(())
    }
}

/// Duty-cycle availability traces with over-selection and a deadline.
pub struct AvailabilitySampler {
    seed: u64,
    participation: f64,
    duty_cycle: f64,
    over_select: f64,
    data_weights: Vec<f64>,
    compute_secs: Vec<f64>,
}

impl AvailabilitySampler {
    pub fn new(
        seed: u64,
        participation: f64,
        duty_cycle: f64,
        over_select: f64,
        data_weights: Vec<f64>,
        compute_secs: Vec<f64>,
    ) -> AvailabilitySampler {
        assert_eq!(data_weights.len(), compute_secs.len());
        AvailabilitySampler {
            seed,
            participation,
            duty_cycle,
            over_select,
            data_weights,
            compute_secs,
        }
    }

    /// Device `device`'s on/off duty-cycle trace at round `round` — a pure
    /// function of `(seed, device, round)`, so any schedule replays it.
    pub fn available(&self, device: usize, round: usize) -> bool {
        let mut rng = Rng::new(
            self.seed
                ^ TRACE_STREAM
                ^ (device as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15)
                ^ (round as u64).wrapping_mul(0xD1B5_4A32_D192_ED03),
        );
        rng.uniform() < self.duty_cycle
    }
}

impl ParticipationSampler for AvailabilitySampler {
    fn name(&self) -> &'static str {
        "availability"
    }

    fn sample(&mut self, round: usize) -> Cohort {
        let n = self.data_weights.len();
        let m = target_cohort_size(n, self.participation);
        let mut avail: Vec<usize> = (0..n).filter(|&i| self.available(i, round)).collect();
        if avail.is_empty() {
            // Floor of 1: an all-off round still trains one device
            // (deterministic round-robin fallback).
            let fallback = round % n;
            return Cohort {
                devices: vec![fallback],
                weights: vec![self.data_weights[fallback]],
            };
        }
        let target = m.min(avail.len());
        // Over-select: contact extra candidates so deadline drops don't
        // shrink the cohort below target.
        let contacted = ((m as f64 * self.over_select).ceil() as usize)
            .clamp(target, avail.len());
        let mut rng = Rng::new(
            self.seed ^ SELECT_STREAM ^ (round as u64).wrapping_mul(0xA24B_AED4_963E_E407),
        );
        rng.shuffle(&mut avail);
        let mut candidates: Vec<usize> = avail.into_iter().take(contacted).collect();
        // Deadline: the round closes once `target` devices have finished —
        // keep the fastest by simulated compute latency (ties by id),
        // dropping the over-selected stragglers.
        candidates.sort_by(|&a, &b| {
            self.compute_secs[a]
                .partial_cmp(&self.compute_secs[b])
                .unwrap_or(std::cmp::Ordering::Equal)
                .then(a.cmp(&b))
        });
        candidates.truncate(target);
        candidates.sort_unstable();
        let weights = candidates.iter().map(|&i| self.data_weights[i]).collect();
        Cohort {
            devices: candidates,
            weights,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg(mode: ParticipationMode, participation: f64, seed: u64) -> ExperimentConfig {
        let mut cfg = ExperimentConfig::default();
        cfg.participation_mode = mode;
        cfg.participation = participation;
        cfg.seed = seed;
        cfg
    }

    #[test]
    fn uniform_replays_the_legacy_rng_stream() {
        let n = 7;
        let weights: Vec<f64> = (0..n).map(|i| 10.0 + i as f64).collect();
        let lat = vec![0.0; n];
        let c = cfg(ParticipationMode::Uniform, 0.5, 42);
        let mut s = build(&c, &weights, &lat);
        // Legacy replica: the pre-sampler coordinator's exact logic.
        let mut legacy = Rng::new(42 ^ 0x5a3c_91f7);
        for round in 0..10 {
            let m = ((n as f64 * 0.5).round() as usize).clamp(1, n);
            let mut idx: Vec<usize> = (0..n).collect();
            legacy.shuffle(&mut idx);
            idx.truncate(m);
            idx.sort_unstable();
            let cohort = s.sample(round);
            assert_eq!(cohort.devices, idx, "round {round}");
            let want: Vec<f64> = idx.iter().map(|&i| weights[i]).collect();
            assert_eq!(cohort.weights, want, "round {round}");
        }
    }

    #[test]
    fn uniform_full_participation_consumes_no_randomness() {
        let weights = vec![5.0; 4];
        let lat = vec![0.0; 4];
        let c = cfg(ParticipationMode::Uniform, 1.0, 9);
        let mut s = build(&c, &weights, &lat);
        for round in 0..5 {
            let cohort = s.sample(round);
            assert_eq!(cohort.devices, vec![0, 1, 2, 3], "round {round}");
            assert_eq!(cohort.total_weight(), 20.0);
        }
    }

    #[test]
    fn importance_weights_sum_to_the_full_corpus() {
        let weights = vec![60.0, 30.0, 10.0, 50.0, 2.0];
        let lat = vec![0.0; 5];
        let c = cfg(ParticipationMode::Importance, 0.6, 3);
        let mut s = build(&c, &weights, &lat);
        let total: f64 = weights.iter().sum();
        for round in 0..50 {
            let cohort = s.sample(round);
            assert!(!cohort.is_empty());
            assert!(cohort.devices.windows(2).all(|w| w[0] < w[1]), "sorted unique");
            assert!(
                (cohort.total_weight() - total).abs() < 1e-9 * total,
                "round {round}: cohort weight {} != corpus {total}",
                cohort.total_weight()
            );
        }
    }

    #[test]
    fn availability_respects_traces_and_deadline() {
        let n = 9;
        let weights: Vec<f64> = vec![3.0; n];
        let lat: Vec<f64> = (0..n).map(|i| (n - i) as f64).collect(); // device 8 fastest
        let mut s = AvailabilitySampler::new(21, 0.5, 0.7, 2.0, weights.clone(), lat);
        for round in 0..60 {
            let cohort = s.sample(round);
            assert!(!cohort.is_empty(), "round {round}");
            assert!(cohort.len() <= ((n as f64 * 0.5).round() as usize), "round {round}");
            assert!(cohort.devices.windows(2).all(|w| w[0] < w[1]));
            for (&d, &w) in cohort.devices.iter().zip(&cohort.weights) {
                assert_eq!(w, weights[d]);
            }
            // Every selected device was on duty (no fallback fires at
            // duty 0.7 with 9 devices under this seed — and if it did,
            // the single fallback device is also a legal cohort).
            if cohort.len() > 1 {
                for &d in &cohort.devices {
                    assert!(s.available(d, round), "round {round}: device {d} off-duty");
                }
            }
        }
    }

    #[test]
    fn availability_deadline_keeps_the_fastest_candidates() {
        // Duty cycle 1.0 ⇒ everyone available; over_select covers the whole
        // fleet ⇒ candidates = all devices ⇒ the deadline must keep exactly
        // the `target` fastest.
        let n = 6;
        let weights = vec![1.0; n];
        let lat = vec![5.0, 1.0, 4.0, 0.5, 3.0, 2.0];
        let mut s = AvailabilitySampler::new(7, 0.5, 1.0, 10.0, weights, lat);
        let cohort = s.sample(0);
        // target = round(6·0.5) = 3 fastest: devices 3 (0.5), 1 (1.0), 5 (2.0).
        assert_eq!(cohort.devices, vec![1, 3, 5]);
    }

    #[test]
    fn builder_dispatches_by_mode() {
        let weights = vec![1.0, 2.0];
        let lat = vec![0.1, 0.2];
        for (mode, name) in [
            (ParticipationMode::Uniform, "uniform"),
            (ParticipationMode::Importance, "importance"),
            (ParticipationMode::Availability, "availability"),
        ] {
            let c = cfg(mode, 1.0, 5);
            let s = build(&c, &weights, &lat);
            assert_eq!(s.name(), name);
            assert_eq!(s.name(), mode.as_str());
        }
    }

    #[test]
    fn cursor_snapshot_resumes_the_sampling_stream() {
        for mode in [ParticipationMode::Uniform, ParticipationMode::Importance] {
            let weights = vec![9.0, 4.0, 7.0, 1.0, 3.0];
            let lat = vec![0.0; 5];
            let c = cfg(mode, 0.5, 77);
            let mut a = build(&c, &weights, &lat);
            for round in 0..3 {
                a.sample(round);
            }
            // Snapshot mid-stream, rebuild fresh, restore the cursor.
            let mut out = ByteWriter::new();
            a.save_state(&mut out);
            let mut b = build(&c, &weights, &lat);
            let bytes = out.into_inner();
            let mut r = ByteReader::new(&bytes);
            b.load_state(&mut r).unwrap();
            r.finish().unwrap();
            for round in 3..8 {
                assert_eq!(a.sample(round), b.sample(round), "{mode:?} round {round}");
            }
        }
    }

    #[test]
    fn target_cohort_size_matches_the_legacy_formula() {
        assert_eq!(target_cohort_size(8, 1.0), 8);
        assert_eq!(target_cohort_size(8, 0.5), 4);
        assert_eq!(target_cohort_size(8, 0.01), 1);
        assert_eq!(target_cohort_size(3, 0.5), 2); // 1.5 rounds away from zero
        assert_eq!(target_cohort_size(1, 0.1), 1);
    }
}
