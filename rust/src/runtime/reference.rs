//! A pure-Rust reference executor: the deterministic stand-in backend.
//!
//! The container build links the vendored `xla` stub, so the AOT artifacts
//! cannot execute and every artifact-gated test skips.  This module closes
//! that gap: [`ReferenceExecutor`] implements the full [`Prog`] contract
//! (init / train / epoch / eval / sgd / grads / sparsify) for a linear
//! softmax classifier in plain `f32` Rust, so the **entire** coordinator
//! loop — local training, compression, streaming aggregation, overlapped
//! eval, ledger — runs and is testable offline.  The algorithm-zoo
//! conformance suite (including its `pipeline_depth` bit-identity sweep),
//! the aggregation/eval benches and the barrier-vs-pipelined
//! `e2e_round` bench are built on it.
//!
//! Semantics mirror the AOT programs:
//! - every call is a **pure function of its arguments** (no hidden state),
//!   so results are bitwise independent of which pool worker serves it;
//! - Adam uses the paper's constants (β₁ = 0.9, β₂ = 0.999, ε = 1e-6);
//! - `eval` returns weighted `(loss_sum, correct, weight_sum)` — a lane
//!   with weight `0.0` contributes exactly nothing, whatever its payload;
//! - `sparsify` applies the shared top-k mask of `|ΔW|` with the kernel's
//!   tie rule (keep every lane with `|ΔW| >= τ`, a superset of k on ties).
//!
//! Model: `logits = W·x + b` with `W: [classes, row]` row-major followed
//! by `b: [classes]`, so `dim = classes·(row + 1)`.

use std::collections::BTreeMap;

use anyhow::{anyhow, Result};

use super::engine::{Arg, Prog};
use super::manifest::ModelMeta;
use super::pool::{EnginePool, Executor};

/// Paper Adam constants (match `artifacts/manifest.json`).
const BETA1: f32 = 0.9;
const BETA2: f32 = 0.999;
const EPS: f32 = 1e-6;

/// Build the [`ModelMeta`] for a reference linear model.
///
/// `dim = num_classes * (row + 1)` where `row = Π input_shape`.
pub fn reference_meta(
    input_shape: &[usize],
    num_classes: usize,
    batch: usize,
    eval_batch: usize,
    epoch_batches: usize,
) -> ModelMeta {
    let row: usize = input_shape.iter().product();
    ModelMeta {
        name: "reference-linear".into(),
        dim: num_classes * (row + 1),
        input_shape: input_shape.to_vec(),
        num_classes,
        batch,
        eval_batch,
        epoch_batches,
        artifacts: BTreeMap::new(),
    }
}

/// An [`EnginePool`] whose every worker runs a [`ReferenceExecutor`].
pub fn reference_pool(meta: ModelMeta, num_workers: usize) -> Result<EnginePool> {
    let factory_meta = meta.clone();
    EnginePool::with_factory(meta, num_workers, move |_worker| {
        ReferenceExecutor::new(factory_meta.clone())
    })
}

/// The deterministic linear-softmax backend (one per pool worker).
pub struct ReferenceExecutor {
    row: usize,
    classes: usize,
    dim: usize,
    /// Fixed scan length of the `epoch` program (`meta.epoch_batches`).
    epoch_batches: usize,
}

impl ReferenceExecutor {
    pub fn new(meta: ModelMeta) -> Result<ReferenceExecutor> {
        let row = meta.row();
        let classes = meta.num_classes;
        if meta.dim != classes * (row + 1) {
            return Err(anyhow!(
                "reference model needs dim = classes*(row+1) = {}, got {}",
                classes * (row + 1),
                meta.dim
            ));
        }
        Ok(ReferenceExecutor {
            row,
            classes,
            dim: meta.dim,
            epoch_batches: meta.epoch_batches.max(1),
        })
    }

    /// Deterministic small-normal init from the seed.
    fn init(&self, seed: i32) -> Vec<f32> {
        let mut rng = crate::rng::Rng::new((seed as i64 as u64) ^ 0x9e37_79b9_7f4a_7c15);
        (0..self.dim).map(|_| (rng.normal() * 0.05) as f32).collect()
    }

    /// `out = W·x + b` for one sample.
    fn logits(&self, w: &[f32], x: &[f32], out: &mut [f32]) {
        let (row, c) = (self.row, self.classes);
        for (cls, o) in out.iter_mut().enumerate() {
            let wrow = &w[cls * row..(cls + 1) * row];
            let mut z = w[c * row + cls];
            for j in 0..row {
                z += wrow[j] * x[j];
            }
            *o = z;
        }
    }

    /// Softmax cross-entropy + prediction for one sample.  `z` holds the
    /// logits on entry and the softmax probabilities on exit.
    fn softmax_loss(z: &mut [f32], label: usize) -> (f32, usize) {
        let max = z.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let mut sum = 0.0f32;
        for v in z.iter_mut() {
            *v = (*v - max).exp();
            sum += *v;
        }
        for v in z.iter_mut() {
            *v /= sum;
        }
        // Argmax with lowest-index tie break (deterministic).
        let mut pred = 0usize;
        for c in 1..z.len() {
            if z[c] > z[pred] {
                pred = c;
            }
        }
        let p_y = z[label].max(f32::MIN_POSITIVE);
        (-(p_y.ln()), pred)
    }

    /// Mean-batch softmax gradient into `g`; returns the mean loss.
    fn grad_batch(&self, w: &[f32], x: &[f32], y: &[i32], g: &mut [f32]) -> f32 {
        let (row, c) = (self.row, self.classes);
        let b = y.len();
        let inv_b = 1.0 / b as f32;
        let mut z = vec![0.0f32; c];
        let mut loss_sum = 0.0f32;
        for i in 0..b {
            let xi = &x[i * row..(i + 1) * row];
            let label = (y[i].rem_euclid(c as i32)) as usize;
            self.logits(w, xi, &mut z);
            let (loss, _pred) = Self::softmax_loss(&mut z, label);
            loss_sum += loss;
            for cls in 0..c {
                let mut gz = z[cls];
                if cls == label {
                    gz -= 1.0;
                }
                let gz = gz * inv_b;
                g[c * row + cls] += gz;
                let grow = &mut g[cls * row..(cls + 1) * row];
                for j in 0..row {
                    grow[j] += gz * xi[j];
                }
            }
        }
        loss_sum * inv_b
    }

    /// One Adam step in place (no bias correction — matches the stateless
    /// AOT `train` program, which has no step counter input).
    fn adam_step(w: &mut [f32], m: &mut [f32], v: &mut [f32], g: &[f32], eta: f32) {
        for i in 0..w.len() {
            m[i] = BETA1 * m[i] + (1.0 - BETA1) * g[i];
            v[i] = BETA2 * v[i] + (1.0 - BETA2) * g[i] * g[i];
            w[i] -= eta * m[i] / (v[i].sqrt() + EPS);
        }
    }

    /// Weighted eval: `(Σ wᵢ·lossᵢ, Σ wᵢ·[predᵢ = yᵢ], Σ wᵢ)`.
    fn eval(&self, w: &[f32], x: &[f32], y: &[i32], wt: &[f32]) -> (f32, f32, f32) {
        let (row, c) = (self.row, self.classes);
        let mut z = vec![0.0f32; c];
        let mut loss_sum = 0.0f32;
        let mut correct = 0.0f32;
        let mut weight = 0.0f32;
        for i in 0..y.len() {
            let xi = &x[i * row..(i + 1) * row];
            let label = (y[i].rem_euclid(c as i32)) as usize;
            self.logits(w, xi, &mut z);
            let (loss, pred) = Self::softmax_loss(&mut z, label);
            loss_sum += wt[i] * loss;
            if pred == label {
                correct += wt[i];
            }
            weight += wt[i];
        }
        (loss_sum, correct, weight)
    }

    /// Shared top-k mask of `|dw|` with the kernel's `|x| >= τ` keep rule.
    fn sparsify(&self, dw: &[f32], dm: &[f32], dv: &[f32], k: i32) -> Vec<Vec<f32>> {
        let k = (k.max(1) as usize).min(self.dim);
        let tau = crate::sparse::top_k_threshold(dw, k);
        let mask = |src: &[f32]| -> Vec<f32> {
            src.iter()
                .zip(dw)
                .map(|(&v, &w)| if w.abs() >= tau { v } else { 0.0 })
                .collect()
        };
        vec![mask(dw), mask(dm), mask(dv)]
    }
}

/// Sequential argument decoder for [`Executor::execute`] calls.
struct ArgStream(std::vec::IntoIter<Arg>);

impl ArgStream {
    fn new(args: Vec<Arg>) -> ArgStream {
        ArgStream(args.into_iter())
    }

    fn next(&mut self) -> Result<Arg> {
        self.0.next().ok_or_else(|| anyhow!("missing argument"))
    }

    fn f32s(&mut self) -> Result<Vec<f32>> {
        match self.next()? {
            Arg::F32(v, _) => Ok(v),
            other => Err(anyhow!("expected f32 tensor, got {other:?}")),
        }
    }

    fn i32s(&mut self) -> Result<Vec<i32>> {
        match self.next()? {
            Arg::I32(v, _) => Ok(v),
            other => Err(anyhow!("expected i32 tensor, got {other:?}")),
        }
    }

    fn sf32(&mut self) -> Result<f32> {
        match self.next()? {
            Arg::ScalarF32(x) => Ok(x),
            other => Err(anyhow!("expected f32 scalar, got {other:?}")),
        }
    }

    fn si32(&mut self) -> Result<i32> {
        match self.next()? {
            Arg::ScalarI32(x) => Ok(x),
            other => Err(anyhow!("expected i32 scalar, got {other:?}")),
        }
    }
}

impl Executor for ReferenceExecutor {
    fn execute(&mut self, prog: Prog, args: Vec<Arg>) -> Result<Vec<Vec<f32>>> {
        let mut a = ArgStream::new(args);
        match prog {
            Prog::Init => {
                let seed = a.si32()?;
                Ok(vec![self.init(seed)])
            }
            Prog::Train => {
                let (mut w, mut m, mut v) = (a.f32s()?, a.f32s()?, a.f32s()?);
                let (x, y, eta) = (a.f32s()?, a.i32s()?, a.sf32()?);
                let mut g = vec![0.0f32; self.dim];
                let loss = self.grad_batch(&w, &x, &y, &mut g);
                Self::adam_step(&mut w, &mut m, &mut v, &g, eta);
                Ok(vec![w, m, v, vec![loss]])
            }
            Prog::Epoch => {
                let (mut w, mut m, mut v) = (a.f32s()?, a.f32s()?, a.f32s()?);
                let (x, y, eta) = (a.f32s()?, a.i32s()?, a.sf32()?);
                // The epoch program is compiled for a fixed scan shape
                // [epoch_batches, batch, ...]; recover it from the meta.
                let nb = self.epoch_batches;
                if y.len() % nb != 0 {
                    return Err(anyhow!("epoch: {} labels not divisible by {nb}", y.len()));
                }
                let b = y.len() / nb;
                let per_sample = self.row;
                if x.len() != nb * b * per_sample {
                    return Err(anyhow!("epoch: ragged batch shapes"));
                }
                let mut loss_sum = 0.0f32;
                for s in 0..nb {
                    let xs = &x[s * b * per_sample..(s + 1) * b * per_sample];
                    let ys = &y[s * b..(s + 1) * b];
                    let mut g = vec![0.0f32; self.dim];
                    let loss = self.grad_batch(&w, xs, ys, &mut g);
                    Self::adam_step(&mut w, &mut m, &mut v, &g, eta);
                    loss_sum += loss;
                }
                Ok(vec![w, m, v, vec![loss_sum / nb as f32]])
            }
            Prog::Eval => {
                let w = a.f32s()?;
                let (x, y, wt) = (a.f32s()?, a.i32s()?, a.f32s()?);
                let (loss, correct, weight) = self.eval(&w, &x, &y, &wt);
                Ok(vec![vec![loss], vec![correct], vec![weight]])
            }
            Prog::Sgd => {
                let mut w = a.f32s()?;
                let (x, y, eta) = (a.f32s()?, a.i32s()?, a.sf32()?);
                let mut g = vec![0.0f32; self.dim];
                let loss = self.grad_batch(&w, &x, &y, &mut g);
                for i in 0..w.len() {
                    w[i] -= eta * g[i];
                }
                Ok(vec![w, vec![loss]])
            }
            Prog::Grads => {
                let w = a.f32s()?;
                let (x, y) = (a.f32s()?, a.i32s()?);
                let mut g = vec![0.0f32; self.dim];
                let loss = self.grad_batch(&w, &x, &y, &mut g);
                Ok(vec![g, vec![loss]])
            }
            Prog::Sparsify => {
                let (dw, dm, dv) = (a.f32s()?, a.f32s()?, a.f32s()?);
                let k = a.si32()?;
                Ok(self.sparsify(&dw, &dm, &dv, k))
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn meta() -> ModelMeta {
        reference_meta(&[2, 2, 1], 3, 2, 4, 2) // row 4, dim 15
    }

    fn exec() -> ReferenceExecutor {
        ReferenceExecutor::new(meta()).unwrap()
    }

    #[test]
    fn init_is_deterministic() {
        let mut e1 = exec();
        let mut e2 = exec();
        let a = e1.execute(Prog::Init, vec![Arg::ScalarI32(7)]).unwrap();
        let b = e2.execute(Prog::Init, vec![Arg::ScalarI32(7)]).unwrap();
        assert_eq!(a, b);
        let c = e1.execute(Prog::Init, vec![Arg::ScalarI32(8)]).unwrap();
        assert_ne!(a, c);
        assert_eq!(a[0].len(), 15);
    }

    #[test]
    fn train_reduces_loss_on_separable_batch() {
        let mut e = exec();
        let w0 = e.execute(Prog::Init, vec![Arg::ScalarI32(1)]).unwrap().remove(0);
        // Two strongly-separated samples.
        let x = vec![1.0, 0.0, 0.0, 0.0, 0.0, 1.0, 0.0, 0.0];
        let y = vec![0, 1];
        let mut w = w0;
        let mut m = vec![0.0; 15];
        let mut v = vec![0.0; 15];
        let mut first = f32::NAN;
        let mut last = f32::NAN;
        for step in 0..50 {
            let out = e
                .execute(
                    Prog::Train,
                    vec![
                        Arg::vec(w.clone()),
                        Arg::vec(m.clone()),
                        Arg::vec(v.clone()),
                        Arg::F32(x.clone(), vec![2, 2, 2, 1]),
                        Arg::I32(y.clone(), vec![2]),
                        Arg::ScalarF32(0.05),
                    ],
                )
                .unwrap();
            let loss = out[3][0];
            if step == 0 {
                first = loss;
            }
            last = loss;
            w = out[0].clone();
            m = out[1].clone();
            v = out[2].clone();
        }
        assert!(last < first, "loss should fall: {first} -> {last}");
        assert!(last.is_finite());
    }

    #[test]
    fn eval_zero_weight_lane_contributes_nothing() {
        let mut e = exec();
        let w = e.execute(Prog::Init, vec![Arg::ScalarI32(3)]).unwrap().remove(0);
        let eval = |e: &mut ReferenceExecutor, x: Vec<f32>, y: Vec<i32>, wt: Vec<f32>| {
            e.execute(
                Prog::Eval,
                vec![
                    Arg::vec(w.clone()),
                    Arg::F32(x, vec![4, 2, 2, 1]),
                    Arg::I32(y, vec![4]),
                    Arg::F32(wt, vec![4]),
                ],
            )
            .unwrap()
        };
        let base_x = vec![0.5f32; 16];
        let mut garbage_x = base_x.clone();
        for v in garbage_x[8..].iter_mut() {
            *v = 42.0; // arbitrary junk in the zero-weight lanes
        }
        let wt = vec![1.0, 1.0, 0.0, 0.0];
        let a = eval(&mut e, base_x, vec![0, 1, 0, 0], wt.clone());
        let b = eval(&mut e, garbage_x, vec![0, 1, 2, 1], wt);
        assert_eq!(a, b, "zero-weight lanes must not affect any output");
        assert_eq!(a[2], vec![2.0]);
    }

    #[test]
    fn sparsify_keeps_shared_mask_with_ties() {
        let mut e = exec();
        let dw = vec![5.0, 0.0, -3.0, 1.0, 0.0, 0.0, 0.0, 0.0, 0.0, 0.0, 0.0, 0.0, 0.0, 0.0, 0.0];
        let dm: Vec<f32> = (0..15).map(|i| i as f32).collect();
        let dv = vec![1.0; 15];
        let out = e
            .execute(
                Prog::Sparsify,
                vec![
                    Arg::vec(dw.clone()),
                    Arg::vec(dm),
                    Arg::vec(dv),
                    Arg::ScalarI32(2),
                ],
            )
            .unwrap();
        // τ = 3.0 ⇒ lanes {0, 2} kept in all three vectors.
        assert_eq!(out[0], {
            let mut v = vec![0.0f32; 15];
            v[0] = 5.0;
            v[2] = -3.0;
            v
        });
        assert_eq!(out[1][0], 0.0); // dm[0] gathered
        assert_eq!(out[1][2], 2.0);
        assert!(out[1][3] == 0.0 && out[2][3] == 0.0, "masked lanes zeroed");
    }

    #[test]
    fn pool_of_reference_executors_round_trips() {
        let pool = reference_pool(meta(), 3).unwrap();
        assert_eq!(pool.num_workers(), 3);
        let h = pool.handle();
        let w = h.init(9).unwrap();
        assert_eq!(w.len(), 15);
        // Same request through different workers is bitwise stable.
        let again = h.init(9).unwrap();
        assert_eq!(w, again);
    }
}
