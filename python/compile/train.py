"""Layer-2 training/eval program builders.

Each ``make_*`` returns a jittable pure function over flat ``f32[d]``
buffers; ``compile/aot.py`` lowers them to HLO text for the rust runtime.
The Adam arithmetic runs through the Layer-1 Pallas kernel
(:func:`compile.kernels.adam_update`), so the kernel lowers into the same
HLO module as the model fwd/bwd.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from compile import kernels
from compile.models.common import Model, softmax_xent, weighted_xent_and_correct

# Paper defaults (§VII-A): beta1=0.9, beta2=0.999, eps=1e-6.
BETA1 = 0.9
BETA2 = 0.999
EPS = 1e-6


def make_loss_fn(model: Model):
    """Mean cross-entropy over a batch, as a function of the flat params."""

    def loss_fn(flat, x, y):
        return softmax_xent(model.apply(flat, x), y)

    return loss_fn


def make_train_step(model: Model):
    """One minibatch Adam step (paper eq. 3-5 through the Pallas kernel).

    Signature: ``(w, m, v, x[B,...], y[B], eta) -> (w', m', v', loss)``.
    ``L`` local epochs = the rust device loops this over its batches, so the
    paper's Fig.-3 local-epoch sweep is a runtime knob.
    """
    loss_fn = make_loss_fn(model)

    def step(w, m, v, x, y, eta):
        loss, g = jax.value_and_grad(loss_fn)(w, x, y)
        w2, m2, v2 = kernels.adam_update(w, m, v, g, eta, BETA1, BETA2, EPS)
        return w2, m2, v2, loss

    return step


def make_epoch_step(model: Model, num_batches: int):
    """A full local epoch as one program: ``lax.scan`` over ``nb`` batches.

    Signature: ``(w, m, v, X[nb,B,...], Y[nb,B], eta) -> (w', m', v',
    mean_loss)``.  This is the perf-pass variant — one PJRT dispatch per
    epoch instead of per batch (DESIGN.md §Perf L2).
    """
    step = make_train_step(model)

    def epoch(w, m, v, xs, ys, eta):
        def body(carry, batch):
            w, m, v = carry
            x, y = batch
            w, m, v, loss = step(w, m, v, x, y, eta)
            return (w, m, v), loss

        (w, m, v), losses = jax.lax.scan(body, (w, m, v), (xs, ys), length=num_batches)
        return w, m, v, jnp.mean(losses)

    return epoch


def make_sgd_step(model: Model):
    """FedSGD baseline step: ``w' = w - eta * g`` (paper eq. 2)."""
    loss_fn = make_loss_fn(model)

    def step(w, x, y, eta):
        loss, g = jax.value_and_grad(loss_fn)(w, x, y)
        return w - eta * g, loss

    return step


def make_grads(model: Model):
    """Flat minibatch gradient — Fig.-1 harness and the theory example."""
    loss_fn = make_loss_fn(model)

    def grads(w, x, y):
        loss, g = jax.value_and_grad(loss_fn)(w, x, y)
        return g, loss

    return grads


def make_eval(model: Model):
    """Weighted eval batch: ``(w, x[E,...], y[E], wt[E]) -> (loss_sum,
    correct, weight_sum)``.  Padding lanes carry weight 0 so the rust side
    can evaluate arbitrary test-set sizes against one compiled shape."""

    def ev(w, x, y, wt):
        logits = model.apply(w, x)
        loss_sum, correct = weighted_xent_and_correct(logits, y, wt)
        return loss_sum, correct, jnp.sum(wt)

    return ev


def make_init(model: Model):
    """Seeded flat init: ``(seed int32) -> f32[d]``."""

    def init(seed):
        return model.init_flat(jax.random.PRNGKey(seed))

    return init


def make_sparsify():
    """Standalone SSM program: ``(dw, dm, dv, k) -> masked triple``."""

    def sp(dw, dm, dv, k):
        return kernels.ssm_sparsify3(dw, dm, dv, k)

    return sp
