//! Minimal benchmarking harness (the offline build has no criterion).
//!
//! `cargo bench` targets use [`Bench`] for wall-clock micro/mesobenchmarks:
//! warmup, auto-calibrated iteration counts, and robust summary stats
//! (mean / p50 / p95 / min).  Results print in a fixed-width table and can
//! be appended to a CSV for the EXPERIMENTS.md §Perf log.

use std::time::{Duration, Instant};

/// Result of one benchmark case.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean_ns: f64,
    pub p50_ns: f64,
    pub p95_ns: f64,
    pub min_ns: f64,
}

impl BenchResult {
    pub fn mean(&self) -> Duration {
        Duration::from_nanos(self.mean_ns as u64)
    }

    /// `name, mean, p50, p95, min` row.
    pub fn row(&self) -> String {
        format!(
            "{:<44} {:>10} {:>12} {:>12} {:>12} {:>12}",
            self.name,
            self.iters,
            fmt_ns(self.mean_ns),
            fmt_ns(self.p50_ns),
            fmt_ns(self.p95_ns),
            fmt_ns(self.min_ns),
        )
    }
}

fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.0} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

/// A group of benchmark cases sharing a target time budget.
pub struct Bench {
    /// Per-case measurement budget.
    pub budget: Duration,
    /// Hard cap on iterations.
    pub max_iters: usize,
    pub results: Vec<BenchResult>,
}

impl Default for Bench {
    fn default() -> Self {
        Bench {
            budget: Duration::from_millis(500),
            max_iters: 10_000,
            results: Vec::new(),
        }
    }
}

impl Bench {
    pub fn new() -> Self {
        Self::default()
    }

    /// Quick-mode harness for CI: tiny budget.
    pub fn quick() -> Self {
        Bench {
            budget: Duration::from_millis(60),
            max_iters: 200,
            results: Vec::new(),
        }
    }

    /// Measure `f`, auto-calibrating the iteration count.
    pub fn run<F: FnMut()>(&mut self, name: impl Into<String>, mut f: F) -> &BenchResult {
        // Warmup + calibration: time a single call.
        let t0 = Instant::now();
        f();
        let once = t0.elapsed().max(Duration::from_nanos(50));
        let iters = ((self.budget.as_nanos() / once.as_nanos().max(1)) as usize)
            .clamp(3, self.max_iters);

        let mut samples = Vec::with_capacity(iters);
        for _ in 0..iters {
            let t = Instant::now();
            f();
            samples.push(t.elapsed().as_nanos() as f64);
        }
        self.results.push(summarize(name.into(), iters, samples));
        self.results.last().unwrap()
    }

    /// Print the group as a table.
    pub fn report(&self, title: &str) {
        println!("\n== {title} ==");
        println!(
            "{:<44} {:>10} {:>12} {:>12} {:>12} {:>12}",
            "case", "iters", "mean", "p50", "p95", "min"
        );
        for r in &self.results {
            println!("{}", r.row());
        }
    }

    /// CSV rows (`case,iters,mean_ns,p50_ns,p95_ns,min_ns`).
    pub fn to_csv(&self) -> String {
        let mut out = String::from("case,iters,mean_ns,p50_ns,p95_ns,min_ns\n");
        for r in &self.results {
            out.push_str(&format!(
                "{},{},{:.1},{:.1},{:.1},{:.1}\n",
                r.name, r.iters, r.mean_ns, r.p50_ns, r.p95_ns, r.min_ns
            ));
        }
        out
    }
}

/// Summarize raw per-iteration samples (ns) into a [`BenchResult`].
///
/// Sorts with [`f64::total_cmp`] so a poisoned sample (NaN from a clock
/// hiccup or a downstream subtraction) sorts above every finite sample
/// instead of panicking the whole harness mid-sweep; the percentiles of
/// a mostly-finite run stay finite, and the mean stays honest (NaN) so
/// the poisoned case is visible in the table rather than fabricated.
fn summarize(name: String, iters: usize, mut samples: Vec<f64>) -> BenchResult {
    samples.sort_by(f64::total_cmp);
    let mean = samples.iter().sum::<f64>() / samples.len() as f64;
    let pct = |p: f64| samples[((samples.len() - 1) as f64 * p) as usize];
    BenchResult {
        name,
        iters,
        mean_ns: mean,
        p50_ns: pct(0.50),
        p95_ns: pct(0.95),
        min_ns: samples[0],
    }
}

/// `FEDADAM_BENCH_QUICK=1` switches every bench binary to quick mode.
pub fn from_env() -> Bench {
    if std::env::var("FEDADAM_BENCH_QUICK").is_ok() {
        Bench::quick()
    } else {
        Bench::new()
    }
}

/// Prevent the optimizer from discarding a computed value.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something() {
        let mut b = Bench::quick();
        let mut acc = 0u64;
        b.run("sum", || {
            acc = black_box((0..1000u64).sum());
        });
        let r = &b.results[0];
        assert!(r.mean_ns > 0.0);
        assert!(r.p95_ns >= r.p50_ns);
        assert!(r.min_ns <= r.mean_ns * 1.5);
        assert!(b.to_csv().lines().count() == 2);
    }

    #[test]
    fn nan_sample_does_not_panic_the_summary() {
        // `partial_cmp(..).unwrap()` would panic here; `total_cmp` sorts
        // the NaN above every finite sample, keeping percentiles finite
        // and leaving the mean NaN as an honest poisoned-run marker.
        let r = summarize("nan".into(), 4, vec![3.0, f64::NAN, 1.0, 2.0]);
        assert_eq!(r.min_ns, 1.0);
        assert_eq!(r.p50_ns, 2.0);
        assert_eq!(r.p95_ns, 3.0);
        assert!(r.mean_ns.is_nan());
    }
}
