"""Layer-2 training programs: Adam-vs-oracle, epoch scan, eval weighting."""

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from compile import train
from compile.kernels import ref as R
from compile.models import get_model
from compile.models.common import softmax_xent


@pytest.fixture(scope="module")
def setup():
    m = get_model("mlp_tiny")
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(16,) + m.input_shape), jnp.float32)
    y = jnp.asarray(rng.integers(0, 10, 16), jnp.int32)
    w = m.init_flat(jax.random.PRNGKey(0))
    return m, w, x, y


def test_train_step_equals_manual_adam(setup):
    """One train_step == grad + the paper's Adam rule (oracle arithmetic)."""
    m, w, x, y = setup
    step = jax.jit(train.make_train_step(m))
    zeros = jnp.zeros_like(w)
    w1, m1, v1, loss = step(w, zeros, zeros, x, y, jnp.float32(1e-3))

    g = jax.grad(lambda w: softmax_xent(m.apply(w, x), y))(w)
    rw, rm, rv = R.adam_update_ref(w, zeros, zeros, g, 1e-3,
                                   train.BETA1, train.BETA2, train.EPS)
    np.testing.assert_allclose(m1, rm, rtol=1e-5, atol=1e-7)
    np.testing.assert_allclose(v1, rv, rtol=1e-5, atol=1e-8)
    np.testing.assert_allclose(w1, rw, rtol=5e-4, atol=5e-4)
    assert float(loss) == pytest.approx(
        float(softmax_xent(m.apply(w, x), y)), rel=1e-5
    )


def test_epoch_equals_sequential_steps(setup):
    m, w, x, y = setup
    nb = 3
    epoch = jax.jit(train.make_epoch_step(m, nb))
    step = jax.jit(train.make_train_step(m))
    xs = jnp.stack([x, x * 0.5, x * 2.0])
    ys = jnp.stack([y, y, y])
    zeros = jnp.zeros_like(w)
    we, me, ve, mean_loss = epoch(w, zeros, zeros, xs, ys, jnp.float32(1e-3))

    ws, ms, vs = w, zeros, zeros
    losses = []
    for i in range(nb):
        ws, ms, vs, l = step(ws, ms, vs, xs[i], ys[i], jnp.float32(1e-3))
        losses.append(float(l))
    np.testing.assert_allclose(we, ws, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(me, ms, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(ve, vs, rtol=1e-5, atol=1e-7)
    assert float(mean_loss) == pytest.approx(np.mean(losses), rel=1e-5)


def test_sgd_step_is_plain_descent(setup):
    m, w, x, y = setup
    sgd = jax.jit(train.make_sgd_step(m))
    w1, loss = sgd(w, x, y, jnp.float32(0.1))
    g = jax.grad(lambda w: softmax_xent(m.apply(w, x), y))(w)
    np.testing.assert_allclose(w1, w - 0.1 * g, rtol=1e-5, atol=1e-6)
    assert np.isfinite(float(loss))


def test_grads_program(setup):
    m, w, x, y = setup
    grads = jax.jit(train.make_grads(m))
    g, loss = grads(w, x, y)
    g2 = jax.grad(lambda w: softmax_xent(m.apply(w, x), y))(w)
    np.testing.assert_allclose(g, g2, rtol=1e-6, atol=1e-7)
    assert float(loss) > 0


def test_eval_weights_mask_padding(setup):
    m, w, x, y = setup
    ev = jax.jit(train.make_eval(m))
    full = jnp.ones(16, jnp.float32)
    half = full.at[8:].set(0.0)
    ls_full, c_full, n_full = ev(w, x, y, full)
    ls_half, c_half, n_half = ev(w, x, y, half)
    assert float(n_full) == 16.0
    assert float(n_half) == 8.0
    assert float(c_half) <= float(c_full) + 1e-6
    # Weighted half-loss equals loss over first 8 rows.
    ls8, _, _ = ev(w, x[:8].repeat(2, 0), y[:8].repeat(2, 0), half)
    # (same rows twice, second half masked -> equals first-8 loss sum)
    manual = float(
        16 * softmax_xent(m.apply(w, x[:8]), y[:8]) / 2
    )
    assert float(ls_half) == pytest.approx(
        float(8 * softmax_xent(m.apply(w, x[:8]), y[:8])), rel=1e-5
    )
    del ls8, manual


def test_eta_is_runtime_knob(setup):
    """Different eta values through ONE jitted step (Fig. 4 sweeps lr)."""
    m, w, x, y = setup
    step = jax.jit(train.make_train_step(m))
    zeros = jnp.zeros_like(w)
    w_small, *_ = step(w, zeros, zeros, x, y, jnp.float32(1e-4))
    w_large, *_ = step(w, zeros, zeros, x, y, jnp.float32(1e-1))
    d_small = float(jnp.linalg.norm(w_small - w))
    d_large = float(jnp.linalg.norm(w_large - w))
    assert d_large > 100 * d_small
