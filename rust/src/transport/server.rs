//! The coordinator's side of the wire: accept agents, drive rounds,
//! validate every uplink byte before it touches the aggregator.
//!
//! [`TransportServer`] runs a single-threaded non-blocking poll loop —
//! no thread per connection, no async runtime.  Each round is one call
//! to [`TransportServer::run_round`]: broadcast the `RoundStart` frame
//! to every registered agent, then pump sockets until every cohort slot
//! has produced a valid uplink.  Uplinks may arrive in **any order**
//! across agents; the caller's sink is invoked with the slot index so
//! slot-fixed accumulation (`ShardedAccumulator::push`) stays
//! bit-identical to the in-process ascending order.
//!
//! Trust boundary: everything read from a socket is hostile until
//! proven otherwise.  A frame must pass, in order: CRC framing
//! ([`super::frame`]), message decode ([`super::msg`]), round/slot/
//! device/weight echo checks against the server's own assignment table,
//! the framed-byte accounting invariant `body.len() == ceil(bits/8)`,
//! and the full wire-codec validation
//! ([`crate::algorithms::wire::WireBody::try_decode`]).  Any failure
//! drops that connection (the agent may reconnect and repair the round);
//! only the round deadline is fatal, and it reports the last violation
//! seen so a systematically-misbehaving agent is diagnosable.

use std::io::Read;
use std::time::{Duration, Instant};

use anyhow::{bail, ensure, Context, Result};

use crate::algorithms::wire::WireBody;
use crate::algorithms::Upload;

use super::frame::{read_frame, write_frame, FrameBuffer};
use super::msg::{Assignment, Msg, Uplink, PROTOCOL_VERSION};
use super::net::{write_all_deadline, Listener, Stream};

/// Poll-loop tick while waiting for bytes.
const POLL_SLEEP: Duration = Duration::from_millis(2);

/// Measured wall-clock uplink latency for one round: for each accepted
/// slot, the elapsed real time from the `RoundStart` broadcast to that
/// slot's validated `Uplink` arriving at the server.  This is *observed*
/// host time — the measured counterpart of the simtime model's
/// *predicted* `sim_secs` — and is pure observability: it never feeds
/// back into anything determinism-bearing, and both fields are `NaN`
/// when no slot was measured.
#[derive(Clone, Copy, Debug)]
pub struct RoundLatency {
    /// The slowest slot's RoundStart→Uplink seconds.
    pub max_secs: f64,
    /// Mean across the round's accepted slots.
    pub mean_secs: f64,
}

impl RoundLatency {
    /// The "no wire, nothing measured" value (both cells `NaN`) — what
    /// an in-process round reports.
    pub fn unmeasured() -> Self {
        RoundLatency {
            max_secs: f64::NAN,
            mean_secs: f64::NAN,
        }
    }
}

/// One registered agent connection.
struct AgentConn {
    stream: Stream,
    frames: FrameBuffer,
    last_activity: Instant,
}

/// Accept loop + round driver for remote device agents.
pub struct TransportServer {
    listener: Listener,
    /// Slot `i` holds agent `i`'s connection; `None` between a drop and
    /// its reconnect.
    conns: Vec<Option<AgentConn>>,
    num_agents: usize,
    dim: usize,
    timeout: Duration,
    fingerprint: u64,
    addr: String,
}

impl TransportServer {
    /// Bind `listen` (TCP `host:port`, port 0 allowed, or `unix:/path`)
    /// and wait for nothing — agents register lazily, on the first
    /// round or whenever they (re)connect.
    pub fn bind(
        listen: &str,
        num_agents: usize,
        timeout_secs: f64,
        fingerprint: u64,
        dim: usize,
    ) -> Result<TransportServer> {
        ensure!(num_agents >= 1, "transport server needs at least one agent");
        let listener = Listener::bind(listen)?;
        let addr = listener.local_addr()?;
        Ok(TransportServer {
            listener,
            conns: (0..num_agents).map(|_| None).collect(),
            num_agents,
            dim,
            timeout: Duration::from_secs_f64(timeout_secs),
            fingerprint,
            addr,
        })
    }

    /// The resolved address agents should connect to (port 0 → real port).
    pub fn addr(&self) -> &str {
        &self.addr
    }

    /// Registration handshake on a freshly-accepted (blocking) stream.
    /// Static so callers holding `&mut self.conns` borrows can use it.
    fn handshake(
        mut stream: Stream,
        fingerprint: u64,
        num_agents: usize,
        dim: usize,
        timeout: Duration,
    ) -> Result<(usize, Stream)> {
        stream.set_read_timeout(Some(timeout))?;
        let payload = read_frame(&mut stream)
            .map_err(|e| anyhow::anyhow!("reading Hello: {e}"))?;
        let msg = Msg::decode(&payload).context("decoding Hello")?;
        let Msg::Hello { version, fingerprint: theirs, agent } = msg else {
            bail!("expected Hello, got {msg:?}");
        };
        ensure!(
            version == PROTOCOL_VERSION,
            "protocol version mismatch: agent speaks {version}, server speaks {PROTOCOL_VERSION}"
        );
        ensure!(
            theirs == fingerprint,
            "config fingerprint mismatch: agent {theirs:#018x}, server {fingerprint:#018x} — \
             the processes resolved different determinism-bearing knobs"
        );
        ensure!(
            (agent as usize) < num_agents,
            "agent index {agent} out of range (transport_agents = {num_agents})"
        );
        write_frame(
            &mut stream,
            &Msg::HelloAck { agents: num_agents as u32, dim: dim as u64 }.encode(),
        )
        .map_err(|e| anyhow::anyhow!("writing HelloAck: {e}"))?;
        stream.set_read_timeout(None)?;
        stream.set_nonblocking(true)?;
        Ok((agent as usize, stream))
    }

    fn install(&mut self, agent: usize, stream: Stream) {
        if self.conns[agent].is_some() {
            log::info!("transport: agent {agent} reconnected, replacing its connection");
        } else {
            log::info!("transport: agent {agent} registered");
        }
        self.conns[agent] = Some(AgentConn {
            stream,
            frames: FrameBuffer::new(),
            last_activity: Instant::now(),
        });
    }

    /// Accept one pending connection and run its handshake, if any.
    /// Handshake failures are logged and swallowed — a bad client must
    /// not take the server down.
    fn poll_register(&mut self) -> Result<Option<usize>> {
        let Some(stream) = self.listener.poll_accept()? else {
            return Ok(None);
        };
        match Self::handshake(stream, self.fingerprint, self.num_agents, self.dim, self.timeout) {
            Ok((agent, stream)) => {
                self.install(agent, stream);
                Ok(Some(agent))
            }
            Err(e) => {
                log::warn!("transport: rejected connection: {e:#}");
                Ok(None)
            }
        }
    }

    /// Block (polling) until every agent slot has a live connection.
    fn ensure_registered(&mut self) -> Result<()> {
        if self.conns.iter().all(|c| c.is_some()) {
            return Ok(());
        }
        let deadline = Instant::now() + self.timeout;
        while self.conns.iter().any(|c| c.is_none()) {
            if self.poll_register()?.is_none() {
                if Instant::now() >= deadline {
                    let missing: Vec<usize> = self
                        .conns
                        .iter()
                        .enumerate()
                        .filter(|(_, c)| c.is_none())
                        .map(|(i, _)| i)
                        .collect();
                    bail!(
                        "transport: agents {missing:?} did not register within {:.1}s",
                        self.timeout.as_secs_f64()
                    );
                }
                std::thread::sleep(Duration::from_millis(5));
            }
        }
        Ok(())
    }

    /// Drive one round: broadcast the downlink, collect one valid uplink
    /// per assignment slot, feed each to `on_uplink(slot, device,
    /// mean_loss, upload)` in arrival order.  Returns the round's
    /// [`RoundLatency`] (measured RoundStart→Uplink wall-clock per slot)
    /// once every slot is filled; errors if the round deadline
    /// (3 × `transport_timeout_secs`) passes with slots missing, or if
    /// the sink itself errors.
    pub fn run_round(
        &mut self,
        round: u64,
        w: &[f32],
        m: Option<&[f32]>,
        v: Option<&[f32]>,
        assignments: &[Assignment],
        mut on_uplink: impl FnMut(usize, usize, f64, Upload) -> Result<()>,
    ) -> Result<RoundLatency> {
        self.ensure_registered()?;
        let downlink = round_start_frame(round, w, m, v, assignments);
        for agent in 0..self.num_agents {
            // A broadcast failure is not fatal: the agent process may have
            // died since it last registered (its connection only surfaces
            // as dead on the next I/O).  Drop the connection and let the
            // poll loop's reconnect + downlink replay repair the round —
            // only the round deadline decides the agent is truly gone.
            if let Err(e) = self.send_frame(agent, &downlink) {
                log::warn!(
                    "transport: sending RoundStart to agent {agent} failed ({e:#}), \
                     dropping its connection and awaiting a reconnect"
                );
                self.conns[agent] = None;
            }
        }
        // Latency is measured from the (attempted) broadcast: a slot
        // served only after a reconnect honestly pays its recovery time.
        let round_sent = Instant::now();
        let mut lat_sum = 0.0f64;
        let mut lat_max = f64::NAN;

        let mut filled = vec![false; assignments.len()];
        let mut done = 0usize;
        let round_deadline = Instant::now() + 3 * self.timeout;
        let mut last_violation: Option<String> = None;
        let mut buf = vec![0u8; 64 * 1024];

        while done < assignments.len() {
            // Late (re)connects: finish the handshake, replay the downlink.
            if let Some(agent) = self.poll_register()? {
                if let Err(e) = self.send_frame(agent, &downlink) {
                    log::warn!("transport: replaying RoundStart to agent {agent} failed: {e:#}");
                    self.conns[agent] = None;
                }
            }

            let mut progressed = false;
            for agent in 0..self.num_agents {
                match self.pump(agent, &mut buf) {
                    Ok(pumped) => progressed |= pumped,
                    Err(e) => {
                        log::warn!("transport: dropping agent {agent}: {e}");
                        last_violation = Some(format!("agent {agent}: {e}"));
                        self.conns[agent] = None;
                        continue;
                    }
                }
                // Drain every complete frame this agent has buffered.
                loop {
                    let popped = match self.conns[agent].as_mut() {
                        Some(conn) => conn.frames.pop(),
                        None => break,
                    };
                    let payload = match popped {
                        Ok(Some(p)) => p,
                        Ok(None) => break,
                        Err(e) => {
                            log::warn!("transport: dropping agent {agent}: bad frame: {e}");
                            last_violation = Some(format!("agent {agent}: {e}"));
                            self.conns[agent] = None;
                            break;
                        }
                    };
                    progressed = true;
                    match accept_uplink(
                        &payload,
                        round,
                        agent,
                        self.num_agents,
                        self.dim,
                        assignments,
                        &filled,
                    ) {
                        Ok(Some((slot, device, mean_loss, upload))) => {
                            // Sink errors are the coordinator's own —
                            // propagate, don't blame the agent.
                            on_uplink(slot, device, mean_loss, upload)?;
                            filled[slot] = true;
                            done += 1;
                            let secs = round_sent.elapsed().as_secs_f64();
                            lat_sum += secs;
                            lat_max = if lat_max.is_nan() { secs } else { lat_max.max(secs) };
                        }
                        Ok(None) => {} // benign duplicate after a replay
                        Err(viol) => {
                            log::warn!("transport: dropping agent {agent}: {viol}");
                            last_violation = Some(format!("agent {agent}: {viol}"));
                            self.conns[agent] = None;
                            break;
                        }
                    }
                }
            }

            if done == assignments.len() {
                break;
            }
            if Instant::now() >= round_deadline {
                let missing: Vec<u32> = assignments
                    .iter()
                    .filter(|a| !filled[a.slot as usize])
                    .map(|a| a.slot)
                    .collect();
                bail!(
                    "transport: round {round} timed out with slots {missing:?} missing{}",
                    match &last_violation {
                        Some(v) => format!(" (last violation: {v})"),
                        None => String::new(),
                    }
                );
            }
            // An agent that owes slots but has gone silent past the
            // timeout gets its connection dropped so a reconnect (with a
            // downlink replay) can repair the round.
            for agent in 0..self.num_agents {
                let owes = assignments
                    .iter()
                    .any(|a| !filled[a.slot as usize] && a.device as usize % self.num_agents == agent);
                if !owes {
                    continue;
                }
                if let Some(conn) = &self.conns[agent] {
                    if conn.last_activity.elapsed() > self.timeout {
                        log::warn!(
                            "transport: agent {agent} silent for {:.1}s with slots outstanding, dropping for reconnect",
                            self.timeout.as_secs_f64()
                        );
                        self.conns[agent] = None;
                    }
                }
            }
            if !progressed {
                std::thread::sleep(POLL_SLEEP);
            }
        }
        Ok(RoundLatency {
            max_secs: lat_max,
            mean_secs: if done == 0 { f64::NAN } else { lat_sum / done as f64 },
        })
    }

    /// Non-blocking drain of agent `agent`'s socket into its frame
    /// buffer.  Returns whether any bytes arrived; errors mean the
    /// connection is dead.
    fn pump(&mut self, agent: usize, buf: &mut [u8]) -> Result<bool> {
        let Some(conn) = self.conns[agent].as_mut() else {
            return Ok(false);
        };
        let mut any = false;
        loop {
            match conn.stream.read(buf) {
                Ok(0) => {
                    if any {
                        // Keep what we read; the close surfaces next poll.
                        break;
                    }
                    bail!("connection closed");
                }
                Ok(n) => {
                    conn.frames.extend(&buf[..n]);
                    conn.last_activity = Instant::now();
                    any = true;
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
                Err(e) => return Err(e.into()),
            }
        }
        Ok(any)
    }

    fn send_frame(&mut self, agent: usize, frame: &[u8]) -> Result<()> {
        let deadline = Instant::now() + self.timeout;
        let Some(conn) = self.conns[agent].as_mut() else {
            bail!("agent {agent} is not connected");
        };
        write_all_deadline(&mut conn.stream, frame, deadline)
    }

    /// Best-effort `Shutdown` broadcast; send errors are ignored (an
    /// agent that already died doesn't need telling).
    pub fn shutdown(&mut self) {
        let mut frame = Vec::new();
        if write_frame(&mut frame, &Msg::Shutdown.encode()).is_err() {
            return;
        }
        for agent in 0..self.num_agents {
            let _ = self.send_frame(agent, &frame);
        }
    }
}

/// Encode one round's downlink as a ready-to-send frame (broadcast to
/// every agent, and replayed to reconnects).
fn round_start_frame(
    round: u64,
    w: &[f32],
    m: Option<&[f32]>,
    v: Option<&[f32]>,
    assignments: &[Assignment],
) -> Vec<u8> {
    let msg = Msg::RoundStart {
        round,
        w: w.to_vec(),
        m: m.map(|x| x.to_vec()),
        v: v.map(|x| x.to_vec()),
        assignments: assignments.to_vec(),
    };
    let payload = msg.encode();
    let mut frame = Vec::with_capacity(payload.len() + super::frame::FRAME_HEADER_LEN);
    write_frame(&mut frame, &payload).expect("Vec<u8> writes cannot fail");
    frame
}

/// Validate one uplink payload end to end.  `Ok(Some(..))` is a fresh,
/// fully-validated slot; `Ok(None)` a benign duplicate (the agent
/// replayed a cached uplink after a downlink replay); `Err` a protocol
/// violation that costs the sender its connection.
fn accept_uplink(
    payload: &[u8],
    round: u64,
    agent: usize,
    num_agents: usize,
    dim: usize,
    assignments: &[Assignment],
    filled: &[bool],
) -> Result<Option<(usize, usize, f64, Upload)>, String> {
    let msg = Msg::decode(payload).map_err(|e| format!("undecodable message: {e:#}"))?;
    let Msg::Uplink(u) = msg else {
        return Err(format!("expected Uplink, got {msg:?}"));
    };
    let Uplink { round: r, slot, device, mean_loss, weight, kind, k, levels, bits, body } = u;
    if r != round {
        return Err(format!("uplink for round {r} during round {round}"));
    }
    let slot = slot as usize;
    if slot >= assignments.len() {
        return Err(format!("slot {slot} out of range ({} assignments)", assignments.len()));
    }
    let a = &assignments[slot];
    if device != a.device {
        return Err(format!("slot {slot} belongs to device {}, uplink claims {device}", a.device));
    }
    if device as usize % num_agents != agent {
        return Err(format!("device {device} is not owned by agent {agent}"));
    }
    if weight.to_bits() != a.weight.to_bits() {
        return Err(format!(
            "weight echo mismatch on slot {slot}: assigned {}, got {weight}",
            a.weight
        ));
    }
    if filled[slot] {
        return Ok(None);
    }
    // Framed-byte accounting: the bytes on the wire must be exactly the
    // priced ledger bits, rounded up to whole bytes.
    if body.len() as u64 != bits.div_ceil(8) {
        return Err(format!(
            "framed-byte accounting violation on slot {slot}: {} body bytes for {bits} priced bits",
            body.len()
        ));
    }
    let k = usize::try_from(k).map_err(|_| format!("mask size {k} overflows"))?;
    let wire = WireBody::try_decode(kind, dim, k, levels, bits, &body)
        .map_err(|e| format!("wire body rejected on slot {slot}: {e}"))?;
    let upload = wire
        .try_into_upload(weight)
        .map_err(|e| format!("wire body inconsistent on slot {slot}: {e}"))?;
    Ok(Some((slot, device as usize, mean_loss, upload)))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn assignments() -> Vec<Assignment> {
        vec![
            Assignment { slot: 0, device: 0, weight: 10.0 },
            Assignment { slot: 1, device: 1, weight: 12.0 },
        ]
    }

    fn dense_uplink(dim: usize) -> Uplink {
        let body = WireBody::Dense3 {
            dw: vec![0.5; dim],
            dm: vec![0.25; dim],
            dv: vec![0.125; dim],
        };
        Uplink {
            round: 4,
            slot: 1,
            device: 1,
            mean_loss: 2.0,
            weight: 12.0,
            kind: body.kind(),
            k: body.k() as u64,
            levels: body.levels(),
            bits: body.wire_bits(),
            body: body.encode(),
        }
    }

    #[test]
    fn accept_uplink_validates_every_echo_field() {
        let dim = 3;
        let asn = assignments();
        let filled = vec![false; 2];
        let good = dense_uplink(dim);
        let ok = accept_uplink(
            &Msg::Uplink(good.clone()).encode(),
            4,
            1,
            2,
            dim,
            &asn,
            &filled,
        )
        .unwrap()
        .unwrap();
        assert_eq!(ok.0, 1);
        assert_eq!(ok.1, 1);
        assert_eq!(ok.2, 2.0);

        // Each corrupted echo field is a violation.
        let mut bad = good.clone();
        bad.round = 5;
        assert!(accept_uplink(&Msg::Uplink(bad).encode(), 4, 1, 2, dim, &asn, &filled).is_err());
        let mut bad = good.clone();
        bad.slot = 7;
        assert!(accept_uplink(&Msg::Uplink(bad).encode(), 4, 1, 2, dim, &asn, &filled).is_err());
        let mut bad = good.clone();
        bad.device = 0; // right slot, wrong device
        assert!(accept_uplink(&Msg::Uplink(bad).encode(), 4, 1, 2, dim, &asn, &filled).is_err());
        let mut bad = good.clone();
        bad.weight = 12.0000001;
        assert!(accept_uplink(&Msg::Uplink(bad).encode(), 4, 1, 2, dim, &asn, &filled).is_err());
        // Wrong owner: device 1 belongs to agent 1 of 2, not agent 0.
        assert!(accept_uplink(&Msg::Uplink(good.clone()).encode(), 4, 0, 2, dim, &asn, &filled)
            .is_err());
    }

    #[test]
    fn accept_uplink_enforces_framed_byte_accounting() {
        let dim = 3;
        let asn = assignments();
        let filled = vec![false; 2];
        let mut padded = dense_uplink(dim);
        padded.body.push(0); // one smuggled unpriced byte
        assert!(
            accept_uplink(&Msg::Uplink(padded).encode(), 4, 1, 2, dim, &asn, &filled).is_err()
        );
        let mut lying = dense_uplink(dim);
        lying.bits += 8; // priced more than framed
        assert!(accept_uplink(&Msg::Uplink(lying).encode(), 4, 1, 2, dim, &asn, &filled).is_err());
    }

    #[test]
    fn duplicate_filled_slot_is_benign() {
        let dim = 3;
        let asn = assignments();
        let filled = vec![false, true];
        let dup = dense_uplink(dim);
        assert!(accept_uplink(&Msg::Uplink(dup).encode(), 4, 1, 2, dim, &asn, &filled)
            .unwrap()
            .is_none());
    }
}
