//! Algorithm-zoo conformance suite.
//!
//! For every algorithm id in the cost table of `rust/src/algorithms/mod.rs`
//! (`fedadam`, `fedadam-top`, `fedadam-ssm`, `fedadam-ssm-m`,
//! `fedadam-ssm-v`, `fairness-top`, `fedadam-ssm-q`, `fedadam-ssm-qef`,
//! `onebit-adam`, `efficient-adam`, `fedsgd` — the eleven-id
//! [`algorithms::CONFORMANCE_ZOO`]), this suite runs a short multi-round
//! coordinator loop on the pure-Rust reference backend (no PJRT artifacts
//! needed — these tests run everywhere) and pins:
//!
//! - the per-round uplink **ledger bits** to the documented cost formula,
//! - the reconstructed **support sizes** to the priced `k`,
//! - the **momentum policy** (aggregated vs device-local `(m, v)`),
//! - full-run **bit-identity** across `num_workers` × `agg_shards`
//!   (× `pipeline_depth`) — for the uniform, importance and availability
//!   participation samplers,
//! - the **simulated clock** (`sim_secs`): worker-count invariance,
//!   monotonicity, eval overlap at `pipeline_depth >= 2`, and the
//!   sparse-beats-dense time-to-accuracy race,
//! - parallel eval **bit-identity** + zero-weight padding neutrality.
//!
//! The CI per-algorithm lane sets `FEDADAM_ALGORITHM` to pin the zoo
//! sweeps to one id (crossed with `FEDADAM_PIPELINE_DEPTH`); the
//! determinism matrix additionally crosses `FEDADAM_PARTICIPATION_MODE ∈
//! {uniform, importance}` through `apply_env_overrides`.  Without the
//! env vars the full zoo runs under the uniform default.

use fedadam_ssm::algorithms::{
    self, Algorithm as _, LocalDelta, MomentumPolicy, Recon, CONFORMANCE_ZOO,
};
use fedadam_ssm::config::{ExperimentConfig, ParticipationMode};
use fedadam_ssm::coordinator::{evaluate_model, evaluate_plan, Coordinator, EvalPlan};
use fedadam_ssm::data::synthetic;
use fedadam_ssm::metrics::ExperimentLog;
use fedadam_ssm::runtime::{reference_meta, reference_pool, ModelMeta};
use fedadam_ssm::sparse::codec::cost;

const INPUT_SHAPE: [usize; 3] = [4, 4, 1]; // row 16
const CLASSES: usize = 10; // matches SyntheticSpec::for_input_shape
const WARMUP: usize = 2;

/// Ids under test: the full eleven-id zoo, or just `FEDADAM_ALGORITHM`
/// when the CI per-algorithm lane pins one.
fn zoo_under_test() -> Vec<&'static str> {
    match std::env::var("FEDADAM_ALGORITHM") {
        Ok(a) if !a.is_empty() => {
            let id = CONFORMANCE_ZOO
                .iter()
                .find(|z| **z == a)
                .unwrap_or_else(|| panic!("FEDADAM_ALGORITHM={a:?} is not in the conformance zoo"));
            vec![*id]
        }
        _ => CONFORMANCE_ZOO.to_vec(),
    }
}

/// Algorithms for the (expensive) full-run bit-identity grids: the default
/// trio of distinct state shapes plus the quantized-SSM pair, or the one
/// id the CI lane pins.
fn identity_zoo() -> Vec<&'static str> {
    match std::env::var("FEDADAM_ALGORITHM") {
        Ok(a) if !a.is_empty() => zoo_under_test(),
        _ => vec![
            "fedadam-ssm",
            "fedadam-ssm-q",
            "fedadam-ssm-qef",
            "onebit-adam",
            "efficient-adam",
        ],
    }
}

fn meta() -> ModelMeta {
    // dim = 10 * (16 + 1) = 170
    reference_meta(&INPUT_SHAPE, CLASSES, 4, 8, 2)
}

fn base_cfg(algo: &str) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::default();
    cfg.name = "conformance".into();
    cfg.model = "reference-linear".into();
    cfg.rounds = 4;
    cfg.devices = 3;
    cfg.local_epochs = 1;
    cfg.max_batches_per_epoch = 2;
    cfg.lr = 0.02;
    cfg.train_samples = 96;
    cfg.test_samples = 50; // NOT a multiple of eval_batch = 8: pads every eval
    cfg.seed = 7;
    cfg.eval_every = 1;
    cfg.quant_levels = 16;
    cfg.warmup_rounds = WARMUP;
    cfg.num_workers = 2;
    cfg.agg_shards = 0; // auto: one shard per pool worker
    // CI determinism-matrix hook (workers/shards/depth/participation
    // mode).  Tests whose expectations depend on the cohort covering
    // every device (ledger totals = devices × formula) pin
    // `participation_mode = Uniform` after this call, exactly like every
    // test pins `algorithm`.
    cfg.apply_env_overrides();
    // FEDADAM_ALGORITHM steers WHICH ids the zoo sweeps run
    // (`zoo_under_test()` / `identity_zoo()` read it directly); each test
    // still pins its current id explicitly here.
    cfg.algorithm = algo.into();
    cfg
}

fn run(cfg: ExperimentConfig) -> (ExperimentLog, Vec<f32>, Vec<f32>, Vec<f32>) {
    let pool = reference_pool(meta(), cfg.num_workers).expect("reference pool");
    let mut coord = Coordinator::with_pool(cfg, pool).expect("coordinator");
    let log = coord.run().expect("run");
    let gs = coord.global();
    (log, gs.w.clone(), gs.m.clone(), gs.v.clone())
}

/// Documented per-device uplink bits for `algo` at round `round`.
fn expected_uplink(algo: &str, round: usize, d: usize, k: usize, s: usize) -> u64 {
    match algo {
        "fedadam" => cost::fedadam_dense(d),
        "fedadam-top" => cost::fedadam_top(d, k),
        "fedadam-ssm" | "fedadam-ssm-m" | "fedadam-ssm-v" | "fairness-top" => {
            cost::fedadam_ssm(d, k)
        }
        "fedadam-ssm-q" | "fedadam-ssm-qef" => cost::fedadam_ssm_q(d, k, s),
        "onebit-adam" => {
            if round < WARMUP {
                cost::fedadam_dense(d)
            } else {
                cost::onebit(d)
            }
        }
        "efficient-adam" => cost::uniform(d, s),
        "fedsgd" => cost::fedsgd_dense(d),
        other => panic!("unknown algorithm {other}"),
    }
}

/// Per-round deltas of a cumulative counter column.
fn per_round(cumulative: impl Iterator<Item = u64>) -> Vec<u64> {
    let totals: Vec<u64> = cumulative.collect();
    std::iter::once(totals[0])
        .chain(totals.windows(2).map(|w| w[1] - w[0]))
        .collect()
}

#[test]
fn ledger_bits_match_cost_table_for_every_algorithm() {
    let m = meta();
    let d = m.dim;
    for algo in zoo_under_test() {
        let mut cfg = base_cfg(algo);
        // Full-cohort expectation (`n × formula` every round) — pin the
        // uniform sampler regardless of the CI lane's mode override.
        cfg.participation_mode = ParticipationMode::Uniform;
        let k = cfg.k_for(d);
        let s = cfg.quant_levels;
        let n = cfg.devices as u64;
        let (log, _, _, _) = run(cfg);
        assert_eq!(log.rounds.len(), 4, "{algo}");
        let up = per_round(log.rounds.iter().map(|r| r.uplink_bits));
        for (t, &bits) in up.iter().enumerate() {
            let want = n * expected_uplink(algo, t, d, k, s);
            assert_eq!(bits, want, "{algo}: round {t} uplink ledger");
        }
        // Downlink: monotone and, for the dense schemes, exactly the
        // documented broadcast cost per receiver.
        let down = per_round(log.rounds.iter().map(|r| r.downlink_bits));
        for (t, &bits) in down.iter().enumerate() {
            assert!(bits > 0, "{algo}: round {t} downlink empty");
            match algo {
                "fedadam" => assert_eq!(bits, n * cost::fedadam_dense(d), "{algo} round {t}"),
                "fedsgd" => assert_eq!(bits, n * cost::fedsgd_dense(d), "{algo} round {t}"),
                "efficient-adam" => {
                    assert_eq!(bits, n * cost::uniform(d, s), "{algo} round {t}")
                }
                "onebit-adam" => {
                    let want = if t < WARMUP {
                        cost::fedadam_dense(d)
                    } else {
                        cost::onebit(d)
                    };
                    assert_eq!(bits, n * want, "{algo} round {t}");
                }
                _ => {} // sparse schemes price the (data-dependent) union support
            }
        }
        // Every logged number stays finite where it must.
        for r in &log.rounds {
            assert!(r.train_loss.is_finite(), "{algo}");
            assert!(r.test_loss.is_finite(), "{algo}");
            assert!(r.test_accuracy.is_finite(), "{algo}");
        }
    }
}

#[test]
fn compressed_support_matches_priced_k() {
    let m = meta();
    let d = m.dim;
    let cfg0 = base_cfg("fedadam");
    let k = cfg0.k_for(d);
    let s = cfg0.quant_levels;
    assert!(k >= 2 && k < d, "test wants a non-trivial k, got {k}");

    // ΔW with FEWER than k non-zeros: the priced top-k support must still
    // be k lanes — zero-valued kept lanes went over the wire too.
    let mut dw = vec![0.0f32; d];
    dw[5] = 3.0;
    dw[d - 3] = -2.0;
    let dm: Vec<f32> = (0..d).map(|i| ((i * 13 % 7) as f32 - 3.0) * 0.01).collect();
    let dv: Vec<f32> = (0..d).map(|i| ((i * 5 % 11) as f32) * 0.001).collect();
    let delta = LocalDelta {
        dw,
        dm,
        dv,
        weight: 32.0,
    };

    let nnz = |r: &Recon| -> usize {
        match r {
            Recon::Dense(v) => v.len(),
            Recon::Sparse(sv) => sv.nnz(),
        }
    };
    let indices = |r: &Recon| -> Option<Vec<u32>> {
        match r {
            Recon::Sparse(sv) => Some(sv.indices.clone()),
            Recon::Dense(_) => None,
        }
    };

    for algo in zoo_under_test() {
        let cfg = base_cfg(algo);
        let mut a = algorithms::build(&cfg, d).unwrap();
        assert_eq!(a.name(), algo);
        for round in 0..4 {
            let up = a.compress(round, 0, delta.clone());
            assert_eq!(
                up.bits,
                expected_uplink(algo, round, d, k, s),
                "{algo}: round {round} priced bits"
            );
            match algo {
                "fedadam-ssm" | "fedadam-ssm-m" | "fedadam-ssm-v" | "fairness-top"
                | "fedadam-ssm-q" | "fedadam-ssm-qef" => {
                    // Shared mask: exactly k stored lanes in ALL THREE
                    // vectors, on identical indices — for the quantized
                    // pair the support must survive dequantization even
                    // where values land on exactly 0.0.
                    assert_eq!(nnz(&up.dw), k, "{algo}: ΔŴ support != priced k");
                    let iw = indices(&up.dw).expect("sparse ΔŴ");
                    let im = indices(up.dm.as_ref().expect("ΔM̂ present")).unwrap();
                    let iv = indices(up.dv.as_ref().expect("ΔV̂ present")).unwrap();
                    assert_eq!(iw, im, "{algo}: mask not shared with ΔM̂");
                    assert_eq!(iw, iv, "{algo}: mask not shared with ΔV̂");
                }
                "fedadam-top" => {
                    // Three independent masks, each exactly k lanes.
                    assert_eq!(nnz(&up.dw), k, "{algo}");
                    assert_eq!(nnz(up.dm.as_ref().unwrap()), k, "{algo}");
                    assert_eq!(nnz(up.dv.as_ref().unwrap()), k, "{algo}");
                }
                "fedadam" => {
                    assert_eq!(nnz(&up.dw), d);
                    assert_eq!(nnz(up.dm.as_ref().unwrap()), d);
                    assert_eq!(nnz(up.dv.as_ref().unwrap()), d);
                }
                "fedsgd" | "efficient-adam" => {
                    assert_eq!(nnz(&up.dw), d, "{algo}");
                    assert!(up.dm.is_none() && up.dv.is_none(), "{algo}: moments on wire");
                }
                "onebit-adam" => {
                    assert_eq!(nnz(&up.dw), d);
                    if round < WARMUP {
                        assert!(up.dm.is_some() && up.dv.is_some(), "warmup is dense FedAdam");
                    } else {
                        assert!(up.dm.is_none() && up.dv.is_none(), "moments frozen after warmup");
                    }
                }
                other => panic!("unhandled {other}"),
            }
        }
    }
}

#[test]
fn momentum_policy_matches_table() {
    let d = meta().dim;
    for algo in zoo_under_test() {
        let cfg = base_cfg(algo);
        let a = algorithms::build(&cfg, d).unwrap();
        for round in 0..4 {
            let want = match algo {
                "efficient-adam" => MomentumPolicy::DeviceLocal,
                "onebit-adam" if round >= WARMUP => MomentumPolicy::DeviceLocal,
                _ => MomentumPolicy::Aggregated,
            };
            assert_eq!(
                a.momentum_policy(round),
                want,
                "{algo}: policy at round {round}"
            );
        }
    }
}

#[test]
fn momentum_policy_is_honored_by_global_state() {
    // Aggregated-moment algorithms must move the server's (M, V);
    // device-local (and momentum-free) algorithms must leave them at the
    // initial zeros — the server never sees their moments.
    for algo in zoo_under_test() {
        let (_, _, m, v) = run(base_cfg(algo));
        let m_moved = m.iter().any(|&x| x != 0.0);
        let v_moved = v.iter().any(|&x| x != 0.0);
        match algo {
            "efficient-adam" | "fedsgd" => {
                assert!(!m_moved, "{algo}: server M mutated without aggregation");
                assert!(!v_moved, "{algo}: server V mutated without aggregation");
            }
            _ => {
                // onebit-adam aggregates during its 2 warmup rounds.
                assert!(m_moved, "{algo}: aggregated M never updated");
                assert!(v_moved, "{algo}: aggregated V never updated");
            }
        }
    }
}

#[test]
fn runs_are_bit_identical_across_workers_and_shards() {
    // The determinism contract: aggregation is shard-order-fixed, eval is
    // batch-order-fixed, training is device-order-fixed — every logged
    // number and the final model must be byte-identical at any
    // (num_workers, agg_shards).
    for algo in identity_zoo() {
        let run_with = |workers: usize, shards: usize| {
            let mut cfg = base_cfg(algo);
            cfg.participation = 0.75; // exercise the sampler path too
            cfg.num_workers = workers;
            cfg.agg_shards = shards;
            run(cfg)
        };
        let (log1, w1, m1, v1) = run_with(1, 1);
        for (workers, shards) in [(2, 1), (1, 4), (3, 7), (2, 170)] {
            let (log, w, m, v) = run_with(workers, shards);
            assert_eq!(w1, w, "{algo} ({workers}w/{shards}s): global W diverged");
            assert_eq!(m1, m, "{algo} ({workers}w/{shards}s): global M diverged");
            assert_eq!(v1, v, "{algo} ({workers}w/{shards}s): global V diverged");
            assert_eq!(log1.rounds.len(), log.rounds.len());
            for (a, b) in log1.rounds.iter().zip(&log.rounds) {
                let tag = format!("{algo} ({workers}w/{shards}s) round {}", a.round);
                assert_eq!(a.train_loss.to_bits(), b.train_loss.to_bits(), "{tag}");
                assert_eq!(a.test_loss.to_bits(), b.test_loss.to_bits(), "{tag}");
                assert_eq!(
                    a.test_accuracy.to_bits(),
                    b.test_accuracy.to_bits(),
                    "{tag}"
                );
                assert_eq!(a.uplink_bits, b.uplink_bits, "{tag}");
                assert_eq!(a.downlink_bits, b.downlink_bits, "{tag}");
                assert_eq!(a.update_norm.to_bits(), b.update_norm.to_bits(), "{tag}");
            }
        }
    }
}

#[test]
fn eval_plan_slicing_is_stable_across_rebuilds() {
    // The round loop hoists test-set pre-slicing into one EvalPlan; this
    // regression-pins that a rebuild at any later round would produce the
    // exact same slice boundaries (and the same evaluation bits as the
    // slice-on-the-fly path).
    let m = meta();
    let spec = synthetic::SyntheticSpec::for_input_shape(&INPUT_SHAPE, 8, 50);
    let task = synthetic::generate(&spec, 11);
    let plan = EvalPlan::new(&task.test, &m);
    let rebuilt = EvalPlan::new(&task.test, &m);
    assert_eq!(plan.boundaries(), rebuilt.boundaries());
    assert_eq!(plan.num_batches(), 50usize.div_ceil(8));
    assert_eq!(plan.boundaries(), EvalPlan::slice_boundaries(50, 8).as_slice());
    // Last batch is ragged: 2 real samples + 6 zero-weight pad lanes.
    assert_eq!(*plan.boundaries().last().unwrap(), (48, 50));

    let pool = reference_pool(m, 2).unwrap();
    let h = pool.handle();
    let w = h.init(3).unwrap();
    let (l1, a1) = evaluate_model(&h, &w, &task.test, 2).unwrap();
    let (l2, a2) = evaluate_plan(&h, &w, &plan, 2).unwrap();
    assert_eq!(l1.to_bits(), l2.to_bits(), "planned eval loss diverged");
    assert_eq!(a1.to_bits(), a2.to_bits(), "planned eval accuracy diverged");
}

#[test]
fn pipelined_loop_is_bit_identical_to_barrier() {
    // PR 3 tentpole contract: `pipeline_depth` may change wall-clock only.
    // Depth 0 is the legacy barrier (batch aggregate + inline eval);
    // depth 1 adds streaming aggregation; depth >= 2 adds train/eval
    // overlap with up to depth-1 evals in flight.  Every logged number,
    // the ledger and the final (W, M, V) must be byte-identical across
    // the depth × workers × shards grid.  eval_every = 2 leaves non-eval
    // rounds in the log, so overlapped evals patch earlier rows while the
    // loop is still running.
    for algo in identity_zoo() {
        let run_with = |depth: usize, workers: usize, shards: usize| {
            let mut cfg = base_cfg(algo);
            cfg.rounds = 5;
            cfg.eval_every = 2;
            cfg.participation = 0.75; // exercise the sampler path too
            cfg.pipeline_depth = depth;
            cfg.num_workers = workers;
            cfg.agg_shards = shards;
            run(cfg)
        };
        let (log0, w0, m0, v0) = run_with(0, 1, 1);
        for (depth, workers, shards) in [(1, 2, 1), (2, 1, 4), (2, 4, 4), (3, 2, 3)] {
            let (log, w, m, v) = run_with(depth, workers, shards);
            assert_eq!(w0, w, "{algo} depth {depth}: global W diverged");
            assert_eq!(m0, m, "{algo} depth {depth}: global M diverged");
            assert_eq!(v0, v, "{algo} depth {depth}: global V diverged");
            assert_eq!(log0.rounds.len(), log.rounds.len());
            for (a, b) in log0.rounds.iter().zip(&log.rounds) {
                let tag = format!("{algo} d{depth} ({workers}w/{shards}s) round {}", a.round);
                assert_eq!(a.train_loss.to_bits(), b.train_loss.to_bits(), "{tag}");
                assert_eq!(a.test_loss.to_bits(), b.test_loss.to_bits(), "{tag}");
                assert_eq!(
                    a.test_accuracy.to_bits(),
                    b.test_accuracy.to_bits(),
                    "{tag}"
                );
                assert_eq!(a.uplink_bits, b.uplink_bits, "{tag}");
                assert_eq!(a.downlink_bits, b.downlink_bits, "{tag}");
                assert_eq!(a.update_norm.to_bits(), b.update_norm.to_bits(), "{tag}");
            }
        }
    }
}

#[test]
fn overlapped_eval_rows_are_patched_before_run_returns() {
    // With pipeline_depth >= 2 an eval-due round's record is returned
    // with NaN eval cells and patched when the overlapped eval lands.
    // `run()` must drain every pending eval, so the returned log carries a
    // finite test metric for every eval-due round — including the last.
    let mut cfg = base_cfg("fedadam-ssm");
    cfg.rounds = 5;
    cfg.eval_every = 2; // eval-due rounds: 0, 2, 4 (last round always due)
    cfg.pipeline_depth = 2;
    let (log, _, _, _) = run(cfg);
    assert_eq!(log.rounds.len(), 5);
    for r in &log.rounds {
        let due = r.round % 2 == 0 || r.round == 4;
        assert_eq!(
            r.test_accuracy.is_finite(),
            due,
            "round {}: eval cell presence must match the eval schedule",
            r.round
        );
        assert_eq!(r.test_loss.is_finite(), due, "round {}", r.round);
    }
}

#[test]
fn eval_padding_is_neutral_and_fanout_bit_identical() {
    let m8 = meta(); // eval_batch = 8
    let pool = reference_pool(m8.clone(), 3).unwrap();
    let h = pool.handle();
    let w = h.init(3).unwrap();

    // 50 test samples: 6 full batches of 8 + one batch of 2 real samples
    // and 6 zero-weight padded lanes.
    let spec = synthetic::SyntheticSpec::for_input_shape(&INPUT_SHAPE, 8, 50);
    let task = synthetic::generate(&spec, 11);
    let data = task.test;
    assert_eq!(data.len(), 50);

    // (a) The parallel fan-out is bit-identical to the sequential path at
    // any worker count.
    let (l1, a1) = evaluate_model(&h, &w, &data, 1).unwrap();
    for workers in [2usize, 3, 8] {
        let (l, a) = evaluate_model(&h, &w, &data, workers).unwrap();
        assert_eq!(l1.to_bits(), l.to_bits(), "{workers} workers: loss diverged");
        assert_eq!(a1.to_bits(), a.to_bits(), "{workers} workers: acc diverged");
    }

    // (b) Zero-weight padded lanes contribute nothing, whatever their
    // payload: the final ragged batch with zero padding vs garbage
    // padding must produce bit-identical engine outputs.
    let row = m8.row();
    let e = m8.eval_batch;
    let start = 48; // last batch: samples 48, 49
    let mut x = Vec::with_capacity(e * row);
    let mut y = Vec::with_capacity(e);
    let mut wt = Vec::with_capacity(e);
    for i in 0..e {
        if i < 2 {
            x.extend_from_slice(data.image(start + i));
            y.push(data.labels[start + i]);
            wt.push(1.0);
        } else {
            x.extend(std::iter::repeat(0.0).take(row));
            y.push(0);
            wt.push(0.0);
        }
    }
    let clean = h.eval_batch(&w, x.clone(), y.clone(), wt.clone()).unwrap();
    let mut x_garbage = x;
    for v in x_garbage[2 * row..].iter_mut() {
        *v = 1e6; // junk payload in every padded lane
    }
    let mut y_garbage = y;
    for l in y_garbage[2..].iter_mut() {
        *l = 9;
    }
    let dirty = h.eval_batch(&w, x_garbage, y_garbage, wt).unwrap();
    assert_eq!(clean, dirty, "zero-weight lanes leaked into the reduction");

    // (c) A batch size that divides the test set exactly (no padding)
    // must agree: accuracy exactly (integer-valued sums), loss to f32
    // regrouping tolerance.
    let m2 = reference_meta(&INPUT_SHAPE, CLASSES, 4, 2, 2);
    let pool2 = reference_pool(m2, 2).unwrap();
    let h2 = pool2.handle();
    let w2 = h2.init(3).unwrap();
    assert_eq!(w, w2, "same seed, same reference init");
    let (l_div, a_div) = evaluate_model(&h2, &w2, &data, 2).unwrap();
    assert_eq!(a1, a_div, "padding changed the accuracy");
    assert!(
        (l1 - l_div).abs() < 1e-3,
        "padded vs exact batching loss drifted: {l1} vs {l_div}"
    );
}

#[test]
fn sampler_modes_hold_the_identity_contract() {
    // Importance and availability cohorts (and the simulated clock) are
    // pure functions of (config, partition, round) — every logged number
    // and the final model must stay byte-identical at any
    // workers × shards × depth.  Depths 0 and 1 share the barrier
    // simulated schedule, so sim_secs is compared there; depth 2 swaps in
    // the overlapped schedule, so only the non-sim fields are compared.
    for mode in [ParticipationMode::Importance, ParticipationMode::Availability] {
        let run_with = |workers: usize, shards: usize, depth: usize| {
            let mut cfg = base_cfg("fedadam-ssm");
            cfg.participation_mode = mode;
            cfg.participation = 0.6;
            cfg.duty_cycle = 0.7;
            cfg.over_select = 2.0;
            cfg.simtime = true;
            cfg.rounds = 5;
            cfg.num_workers = workers;
            cfg.agg_shards = shards;
            cfg.pipeline_depth = depth;
            run(cfg)
        };
        let (log1, w1, m1, v1) = run_with(1, 1, 0);
        for (workers, shards, depth) in [(2, 1, 0), (1, 4, 1), (3, 3, 1), (2, 2, 2)] {
            let (log, w, m, v) = run_with(workers, shards, depth);
            let mode = mode.as_str();
            assert_eq!(w1, w, "{mode} ({workers}w/{shards}s/d{depth}): W diverged");
            assert_eq!(m1, m, "{mode} ({workers}w/{shards}s/d{depth}): M diverged");
            assert_eq!(v1, v, "{mode} ({workers}w/{shards}s/d{depth}): V diverged");
            assert_eq!(log1.rounds.len(), log.rounds.len());
            for (a, b) in log1.rounds.iter().zip(&log.rounds) {
                let tag = format!("{mode} ({workers}w/{shards}s/d{depth}) round {}", a.round);
                assert_eq!(a.train_loss.to_bits(), b.train_loss.to_bits(), "{tag}");
                assert_eq!(a.test_loss.to_bits(), b.test_loss.to_bits(), "{tag}");
                assert_eq!(a.test_accuracy.to_bits(), b.test_accuracy.to_bits(), "{tag}");
                assert_eq!(a.uplink_bits, b.uplink_bits, "{tag}");
                assert_eq!(a.downlink_bits, b.downlink_bits, "{tag}");
                assert_eq!(a.update_norm.to_bits(), b.update_norm.to_bits(), "{tag}");
                if depth <= 1 {
                    assert_eq!(a.sim_secs.to_bits(), b.sim_secs.to_bits(), "{tag}: sim");
                }
            }
        }
    }
}

#[test]
fn simulated_clock_is_identical_at_any_worker_count() {
    // Virtual time must never read real time: the sim_secs column is a
    // pure function of (config, partition, wire bits), so it is
    // bit-identical at any num_workers / agg_shards (and across depths 0
    // and 1, which share the barrier schedule), finite, positive and
    // monotone — and a repeated run reproduces it exactly.
    let run_with = |workers: usize, shards: usize, depth: usize| {
        let mut cfg = base_cfg("fedadam-ssm-q");
        cfg.participation_mode = ParticipationMode::Uniform;
        cfg.participation = 0.75;
        cfg.simtime = true;
        cfg.num_workers = workers;
        cfg.agg_shards = shards;
        cfg.pipeline_depth = depth;
        run(cfg)
    };
    let (log1, _, _, _) = run_with(1, 1, 0);
    let mut prev = 0.0;
    for r in &log1.rounds {
        assert!(r.sim_secs.is_finite() && r.sim_secs > 0.0, "round {}", r.round);
        assert!(r.sim_secs >= prev, "round {}: clock ran backwards", r.round);
        prev = r.sim_secs;
    }
    for (workers, shards, depth) in [(2, 1, 0), (4, 4, 0), (1, 4, 1), (3, 2, 1), (1, 1, 0)] {
        let (log, _, _, _) = run_with(workers, shards, depth);
        for (a, b) in log1.rounds.iter().zip(&log.rounds) {
            assert_eq!(
                a.sim_secs.to_bits(),
                b.sim_secs.to_bits(),
                "({workers}w/{shards}s/d{depth}) round {}: simulated clock drifted",
                a.round
            );
        }
    }
    // simtime off ⇒ the column is absent (NaN), never zero-filled.
    let mut cfg = base_cfg("fedadam-ssm-q");
    cfg.participation_mode = ParticipationMode::Uniform;
    cfg.simtime = false;
    let (dry, _, _, _) = run(cfg);
    assert!(dry.rounds.iter().all(|r| r.sim_secs.is_nan()));
}

#[test]
fn overlapped_schedule_hides_eval_time() {
    // Same experiment, barrier vs overlapped simulated schedule: with an
    // eval every round, the overlapped clock must finish strictly earlier
    // (each eval hides under the next round's training) while every
    // non-sim number stays byte-identical (the existing depth-identity
    // contract).
    let run_with = |depth: usize| {
        let mut cfg = base_cfg("fedadam-ssm");
        cfg.participation_mode = ParticipationMode::Uniform;
        cfg.simtime = true;
        cfg.eval_every = 1;
        cfg.rounds = 4;
        cfg.pipeline_depth = depth;
        run(cfg)
    };
    let (barrier, wb, _, _) = run_with(0);
    let (overlap, wo, _, _) = run_with(2);
    assert_eq!(wb, wo, "depth must not change the model");
    let t_barrier = barrier.rounds.last().unwrap().sim_secs;
    let t_overlap = overlap.rounds.last().unwrap().sim_secs;
    assert!(
        t_overlap < t_barrier,
        "overlap must hide eval time: {t_overlap} !< {t_barrier}"
    );
}

#[test]
fn sparse_uplinks_win_the_simulated_time_race() {
    // The metric that motivates the whole paper: on a bandwidth-bound
    // fleet, FedAdam-SSM (and its quantized composition) must reach the
    // common accuracy target in less *simulated* time than dense FedAdam,
    // because the per-round uplink is the critical path.
    let run_algo = |algo: &str| {
        let mut cfg = base_cfg(algo);
        cfg.participation_mode = ParticipationMode::Uniform;
        cfg.simtime = true;
        cfg.sim_bandwidth_mbps = 0.01; // 10 kbit/s uplinks
        cfg.rounds = 6;
        run(cfg).0
    };
    let dense = run_algo("fedadam");
    let ssm = run_algo("fedadam-ssm");
    let ssm_q = run_algo("fedadam-ssm-q");
    let target = dense
        .best_accuracy()
        .min(ssm.best_accuracy())
        .min(ssm_q.best_accuracy());
    let t_dense = dense.time_to_accuracy(target).expect("dense never hit target");
    let t_ssm = ssm.time_to_accuracy(target).expect("ssm never hit target");
    let t_ssm_q = ssm_q.time_to_accuracy(target).expect("ssm-q never hit target");
    assert!(
        t_ssm < t_dense,
        "SSM must win the time race: {t_ssm}s !< {t_dense}s (target {target:.3})"
    );
    assert!(
        t_ssm_q < t_dense,
        "SSM-Q must win the time race: {t_ssm_q}s !< {t_dense}s (target {target:.3})"
    );
}

#[test]
fn reference_backend_full_loop_is_reproducible() {
    // Two independently-built coordinators with the same config produce
    // the same experiment — the reference backend holds the same purity
    // contract the PJRT pool does.
    let run_once = || run(base_cfg("fedadam-ssm"));
    let (log_a, w_a, _, _) = run_once();
    let (log_b, w_b, _, _) = run_once();
    assert_eq!(w_a, w_b);
    for (a, b) in log_a.rounds.iter().zip(&log_b.rounds) {
        assert_eq!(a.train_loss.to_bits(), b.train_loss.to_bits());
        assert_eq!(a.test_accuracy.to_bits(), b.test_accuracy.to_bits());
        assert_eq!(a.uplink_bits, b.uplink_bits);
    }
}
