//! Quantizer microbench: 1-bit EF and s-level uniform compressors (the
//! baselines' hot path) across model dimensions, plus the PR-10 fused
//! SSM-Q wire encoder against the staged gather→quantize→pack pipeline
//! it replaced (byte-identity re-asserted outside the timed region).
//!
//! Run: `cargo bench --bench quant`.
//!
//! **JSON mode** (`-- --json`) — the CI perf pin: the dense quantizers
//! and the fused-vs-staged SSM-Q encode at the small and large model
//! scales, emitting per-case `median_ns` plus the derived fused-encode
//! speedups as `BENCH_quant.json` (`--json-out PATH` to redirect).
//! With `--baseline PATH` any >10% regression against the checked-in
//! pin prints a `WARN:` line (informational — absolute numbers are
//! host-dependent).

use std::collections::BTreeMap;

use fedadam_ssm::algorithms::wire::WireBody;
use fedadam_ssm::benchlib::{black_box, from_env, pin};
use fedadam_ssm::quant::sparse_uniform::{ssm_q_encode, ssm_q_encode_fused};
use fedadam_ssm::quant::{onebit_compress, uniform_compress, ErrorFeedback};
use fedadam_ssm::rng::Rng;
use fedadam_ssm::sparse::top_k_indices;
use fedadam_ssm::util::json::Value;

const S_LEVELS: u32 = 16;

/// The staged wire path the fused encoder replaced: gather the kept
/// lanes into value lists, quantize each against its own scale, then
/// bit-pack mask + codes into the body bytes.
fn staged_encode(d: usize, idx: &[u32], dw: &[f32], dm: &[f32], dv: &[f32]) -> Vec<u8> {
    let gather = |src: &[f32]| -> Vec<f32> { idx.iter().map(|&i| src[i as usize]).collect() };
    let msg = ssm_q_encode(d, idx, &gather(dw), &gather(dm), &gather(dv), S_LEVELS);
    WireBody::SsmQ(msg).encode()
}

/// `--json` mode: the machine-readable perf pin (see the module docs).
fn json_mode(args: &[String]) {
    let out_path = pin::opt(args, "--json-out").unwrap_or_else(|| "BENCH_quant.json".into());
    let baseline = pin::opt(args, "--baseline");

    let mut bench = from_env();
    let mut rng = Rng::new(3);
    let mut cases: Vec<Value> = Vec::new();
    let mut medians: BTreeMap<String, f64> = BTreeMap::new();
    let mut speedups = BTreeMap::new();
    for &d in &[54_314usize, 1_663_370] {
        let x: Vec<f32> = (0..d).map(|_| rng.normal() as f32).collect();
        let k = d / 20;
        let idx = top_k_indices(&x, k);
        let dm: Vec<f32> = (0..d).map(|_| rng.normal() as f32 * 0.1).collect();
        let dv: Vec<f32> = (0..d).map(|_| (rng.normal() as f32).abs() * 0.01).collect();
        let mut ef = ErrorFeedback::new(d);

        let mut timed = BTreeMap::new();
        for (name, f) in [
            (format!("onebit-ef-d{d}"), 0usize),
            (format!("uniform-s{S_LEVELS}-d{d}"), 1),
            (format!("staged-ssm-q-encode-d{d}"), 2),
            (format!("fused-ssm-q-encode-d{d}"), 3),
        ] {
            let med = bench
                .run(name.clone(), || match f {
                    0 => {
                        black_box(onebit_compress(&x, &mut ef));
                    }
                    1 => {
                        black_box(uniform_compress(&x, S_LEVELS));
                    }
                    2 => {
                        black_box(staged_encode(d, &idx, &x, &dm, &dv));
                    }
                    _ => {
                        black_box(ssm_q_encode_fused(d, &idx, &x, &dm, &dv, S_LEVELS));
                    }
                })
                .p50_ns;
            timed.insert(name.clone(), med);
            medians.insert(name.clone(), med);
            let mut extra = BTreeMap::new();
            extra.insert("dim".into(), Value::Num(d as f64));
            cases.push(pin::case(&name, "median_ns", med, extra));
        }
        // Byte-identity re-check outside the timed region.
        assert_eq!(
            ssm_q_encode_fused(d, &idx, &x, &dm, &dv, S_LEVELS).bytes,
            staged_encode(d, &idx, &x, &dm, &dv),
            "d={d}: fused encode diverged from the staged pipeline"
        );
        speedups.insert(
            format!("d{d}"),
            Value::Num(
                timed[&format!("staged-ssm-q-encode-d{d}")]
                    / timed[&format!("fused-ssm-q-encode-d{d}")].max(1.0),
            ),
        );
    }

    let mut extra = BTreeMap::new();
    extra.insert("s_levels".into(), Value::Num(S_LEVELS as f64));
    extra.insert("fused_encode_speedup".into(), Value::Obj(speedups));
    pin::write(
        "quant",
        "maintainer-machine pin; regenerate with: cargo bench --bench quant -- --json \
         --json-out BENCH_quant.json (PR 10 fused sparsify->quantize->pack into one pass \
         over the kept lanes — byte-identical output, pinned here at >=2x under the staged \
         gather+quantize+pack cases it replaced; medians are host-dependent, so ci_local.sh \
         only WARNS on >10% regressions)",
        &out_path,
        cases,
        extra,
    );

    if let Some(bp) = baseline {
        pin::compare_with_baseline(&bp, "median_ns", &medians);
    }
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if args.iter().any(|a| a == "--json") {
        json_mode(&args);
        return;
    }
    let mut bench = from_env();
    let mut rng = Rng::new(3);

    for &d in &[54_314usize, 176_778, 1_663_370] {
        let x: Vec<f32> = (0..d).map(|_| rng.normal() as f32).collect();
        let mut ef = ErrorFeedback::new(d);
        bench.run(format!("onebit+EF d={d}"), || {
            black_box(onebit_compress(&x, &mut ef));
        });
        for &s in &[4u32, 16, 256] {
            bench.run(format!("uniform s={s} d={d}"), || {
                black_box(uniform_compress(&x, s));
            });
        }
        // Fused vs staged SSM-Q wire encode at the paper's alpha = 0.05.
        let k = d / 20;
        let idx = top_k_indices(&x, k);
        let dm: Vec<f32> = (0..d).map(|_| rng.normal() as f32 * 0.1).collect();
        let dv: Vec<f32> = (0..d).map(|_| (rng.normal() as f32).abs() * 0.01).collect();
        bench.run(format!("staged ssm-q encode d={d} k={k}"), || {
            black_box(staged_encode(d, &idx, &x, &dm, &dv));
        });
        bench.run(format!("fused ssm-q encode d={d} k={k}"), || {
            black_box(ssm_q_encode_fused(d, &idx, &x, &dm, &dv, S_LEVELS));
        });
        assert_eq!(
            ssm_q_encode_fused(d, &idx, &x, &dm, &dv, S_LEVELS).bytes,
            staged_encode(d, &idx, &x, &dm, &dv),
            "d={d}: fused encode diverged from the staged pipeline"
        );
    }

    bench.report("quantizers + fused wire encode");
    println!("\n{}", bench.to_csv());
}
