//! End-to-end validation driver (EXPERIMENTS.md §E2E).
//!
//! Exercises the FULL stack on a real small workload: the paper CNN on the
//! Fashion-MNIST-shaped synthetic corpus, 8 devices, non-IID Dirichlet(0.1),
//! a few hundred communication-equivalents of training — proving all three
//! layers compose: Pallas Adam kernel → JAX model AOT → PJRT execution →
//! rust coordination, sparsification, aggregation, evaluation.
//!
//! ```text
//! cargo run --release --example e2e_train [-- --rounds 60]
//! ```
//!
//! Writes `results/e2e_train.csv` with the loss curve.

use anyhow::Result;
use fedadam_ssm::cli::Cli;
use fedadam_ssm::config::ExperimentConfig;
use fedadam_ssm::coordinator::Coordinator;

fn main() -> Result<()> {
    let cli = Cli::parse(std::env::args().skip(1))?;
    let rounds: usize = cli.opt_parse("rounds")?.unwrap_or(60);

    let mut cfg = ExperimentConfig::default();
    cfg.name = "e2e".into();
    cfg.model = cli.opt_or("model", "cnn_small").to_string();
    cfg.algorithm = "fedadam-ssm".into();
    cfg.rounds = rounds;
    cfg.devices = 8;
    cfg.local_epochs = 2;
    cfg.max_batches_per_epoch = 4;
    cfg.train_samples = 4096;
    cfg.test_samples = 1024;
    cfg.iid = false; // the paper's hard setting
    cfg.dirichlet_theta = 0.1;
    cfg.sparsity = 0.05;
    cfg.eval_every = 2;
    // Engine-pool workers (`--workers 0` = one per core) and server-reduce
    // lane shards (`--shards 0` = one per worker). Any combination gives
    // bit-identical results; only wall-clock changes.
    cfg.num_workers = cli.opt_parse("workers")?.unwrap_or(0);
    cfg.agg_shards = cli.opt_parse("shards")?.unwrap_or(0);

    eprintln!(
        "e2e: {} devices x {} local epochs x {} rounds on {} (non-IID Dirichlet {})",
        cfg.devices, cfg.local_epochs, cfg.rounds, cfg.model, cfg.dirichlet_theta
    );
    let t0 = std::time::Instant::now();
    let mut coord = Coordinator::new(cfg, cli.opt_or("artifacts", "artifacts"))?;
    let log = coord.run()?;
    let wall = t0.elapsed().as_secs_f64();

    // Loss-curve summary to stdout.
    println!("{:>5} {:>12} {:>10} {:>10} {:>14}", "round", "train loss", "test loss", "test acc", "uplink Mbit");
    for r in log.rounds.iter().filter(|r| r.test_accuracy.is_finite()) {
        println!(
            "{:>5} {:>12.4} {:>10.4} {:>10.3} {:>14.2}",
            r.round,
            r.train_loss,
            r.test_loss,
            r.test_accuracy,
            r.uplink_bits as f64 / 1e6
        );
    }
    std::fs::create_dir_all("results")?;
    log.write_csv("results/e2e_train.csv")?;
    println!("\n{}", log.summary());
    println!("total wall time {wall:.1}s; wrote results/e2e_train.csv");

    // Hard assertions: the run must actually have learned.
    let first = log.rounds.first().unwrap().train_loss;
    let last = log.rounds.last().unwrap().train_loss;
    let best = log.best_accuracy();
    anyhow::ensure!(last < first * 0.6, "loss did not fall: {first} -> {last}");
    anyhow::ensure!(best > 0.5, "accuracy never beat 0.5: {best}");
    println!("E2E OK: loss {first:.3} -> {last:.3}, best acc {best:.3}");
    Ok(())
}
