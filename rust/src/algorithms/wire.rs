//! The transport form of one device's compressed uplink.
//!
//! In-process, [`Algorithm::compress`] hands the coordinator an [`Upload`]
//! whose payloads are already *decoded* (the dequantized / gathered values
//! the server aggregates).  On a real socket the compressed message itself
//! must cross the wire, and the cost ledger's claim — `bits` per message —
//! must be the literal framed size.  [`WireBody`] is that message: every
//! variant encodes to **one contiguous LSB-first bitstream** whose byte
//! length is exactly `ceil(wire_bits / 8)`, so the priced ledger formula
//! and the bytes on the wire cannot drift apart.  (Concatenating
//! separately-padded sections would not be honest: three index-list masks
//! each waste up to 7 padding bits, but the ledger prices the contiguous
//! sum.)
//!
//! Decoding is strictly **untrusted**: [`WireBody::try_decode`] accepts
//! only the canonical encoder output — exact byte length, exactly-`k`
//! strictly-increasing positions `< dim`, on-grid quantizer codes, finite
//! scales, zero padding bits — and never panics on malformed or truncated
//! bytes ([`DecodeError`] otherwise).  [`WireBody::try_into_upload`] then
//! reconstructs the *identical* [`Upload`] the in-process path produces,
//! which is what makes the multi-process run bit-identical to the
//! in-process one.
//!
//! [`Algorithm::compress`]: super::Algorithm::compress

use anyhow::{bail, ensure, Result};

use super::{Recon, Upload};
use crate::quant::sparse_uniform::try_ssm_q_decode;
use crate::quant::{
    try_onebit_decompress, try_uniform_decompress, OneBitPacket, SparseUniformPacket, SsmQUplink,
    UniformPacket,
};
use crate::sparse::codec::{
    cost, decode_positions, encode_positions, index_bits, mask_bits, pack_positions, BitPacker,
    BitUnpacker, DecodeError, MaskEncoding, Q,
};
use crate::sparse::SparseVec;

/// One compressed uplink message in transport form: the body plus the
/// FedAvg weight and the priced bit cost the ledger will be charged.
#[derive(Clone, Debug)]
pub struct WireUpload {
    pub body: WireBody,
    /// FedAvg weight (`|D̃_n|`).
    pub weight: f64,
    /// The algorithm's priced uplink cost for this message — enforced
    /// against the framed size at send time by [`WireUpload::encode_body`].
    pub bits: u64,
}

impl WireUpload {
    /// Derive the transport form from an in-process [`Upload`] — the
    /// default for algorithms whose upload payloads *are* their wire
    /// payloads (dense f32 and sparse f32 families).  Quantized
    /// algorithms override [`Algorithm::compress_wire`] instead, because
    /// their uploads carry dequantized values whose f32 re-encoding would
    /// be neither the priced size nor the original codes.
    ///
    /// [`Algorithm::compress_wire`]: super::Algorithm::compress_wire
    pub fn from_upload(up: Upload) -> Result<WireUpload> {
        let body = match (up.dw, up.dm, up.dv) {
            (Recon::Dense(dw), Some(Recon::Dense(dm)), Some(Recon::Dense(dv))) => {
                WireBody::Dense3 { dw, dm, dv }
            }
            (Recon::Dense(dw), None, None) => WireBody::Dense1 { dw },
            (Recon::Sparse(w), Some(Recon::Sparse(m)), Some(Recon::Sparse(v))) => {
                ensure!(
                    w.dim == m.dim && w.dim == v.dim,
                    "sparse triple with mismatched dims"
                );
                if w.indices == m.indices && w.indices == v.indices {
                    WireBody::SharedMask {
                        dim: w.dim,
                        indices: w.indices,
                        w: w.values,
                        m: m.values,
                        v: v.values,
                    }
                } else {
                    ensure!(
                        w.nnz() == m.nnz() && w.nnz() == v.nnz(),
                        "sparse triple with unequal supports has no single-k wire form"
                    );
                    WireBody::SparseTriple { w, m, v }
                }
            }
            _ => bail!("upload shape has no derivable wire form; override compress_wire"),
        };
        Ok(WireUpload {
            body,
            weight: up.weight,
            bits: up.bits,
        })
    }

    /// Serialize the body, enforcing — in **all** build profiles, not just
    /// debug — that the priced ledger cost equals the framed size:
    /// `body.wire_bits() == self.bits` and the byte length is exactly
    /// `ceil(bits / 8)`.  A mispriced message is refused at send time
    /// instead of silently corrupting the cost ledger.
    pub fn encode_body(&self) -> Result<Vec<u8>> {
        let wire = self.body.wire_bits();
        ensure!(
            wire == self.bits,
            "mispriced uplink: ledger prices {} bits but the wire body is {} bits",
            self.bits,
            wire
        );
        let bytes = self.body.encode();
        ensure!(
            bytes.len() as u64 == self.bits.div_ceil(8),
            "framed-byte accounting violated: {} bytes on the wire for {} priced bits",
            bytes.len(),
            self.bits
        );
        Ok(bytes)
    }
}

/// The compressed payload of one uplink, by algorithm family.
#[derive(Clone, Debug)]
pub enum WireBody {
    /// Dense `(ΔW, ΔM, ΔV)` — `fedadam`, `onebit-adam` warmup.  `3dq` bits.
    Dense3 {
        dw: Vec<f32>,
        dm: Vec<f32>,
        dv: Vec<f32>,
    },
    /// Dense `ΔW` only — `fedsgd`.  `dq` bits.
    Dense1 { dw: Vec<f32> },
    /// One shared mask + three kept-value f32 lists — the SSM family
    /// (`fedadam-ssm`/`-m`/`-v`/`-ef`, `fairness-top`).
    /// `min{3kq+d, k(3q+log₂d)}` bits.
    SharedMask {
        dim: usize,
        indices: Vec<u32>,
        w: Vec<f32>,
        m: Vec<f32>,
        v: Vec<f32>,
    },
    /// Three independently-masked sparse f32 vectors, equal `k` —
    /// `fedadam-top`.  `min{3(kq+d), 3k(q+log₂d)}` bits.
    SparseTriple {
        w: SparseVec,
        m: SparseVec,
        v: SparseVec,
    },
    /// Quantized shared mask — `fedadam-ssm-q`/`-qef`.
    SsmQ(SsmQUplink),
    /// A body the fused device-side encoders already serialized: the
    /// canonical contiguous bitstream of the `kind`-tagged variant, plus
    /// the header fields the transport frame needs.  [`WireBody::encode`]
    /// returns the bytes verbatim (the fused encoder is debug-asserted
    /// byte-identical to the staged path), so the hot path never re-packs.
    /// Never produced by [`WireBody::try_decode`] — decoding yields the
    /// structured variant.
    Packed {
        kind: u8,
        dim: usize,
        k: usize,
        levels: u32,
        bytes: Vec<u8>,
    },
    /// Error-compensated sign quantization — `onebit-adam` post-warmup.
    OneBit(OneBitPacket),
    /// Dense s-level uniform quantization — `efficient-adam`.
    UniformQ(UniformPacket),
}

/// Wire kind tags (the transport header's `kind` byte).
pub const KIND_DENSE3: u8 = 1;
pub const KIND_DENSE1: u8 = 2;
pub const KIND_SHARED_MASK: u8 = 3;
pub const KIND_SPARSE_TRIPLE: u8 = 4;
pub const KIND_SSM_Q: u8 = 5;
pub const KIND_ONEBIT: u8 = 6;
pub const KIND_UNIFORM_Q: u8 = 7;

impl WireBody {
    /// Header tag identifying the variant on the wire.
    pub fn kind(&self) -> u8 {
        match self {
            WireBody::Dense3 { .. } => KIND_DENSE3,
            WireBody::Dense1 { .. } => KIND_DENSE1,
            WireBody::SharedMask { .. } => KIND_SHARED_MASK,
            WireBody::SparseTriple { .. } => KIND_SPARSE_TRIPLE,
            WireBody::SsmQ(_) => KIND_SSM_Q,
            WireBody::Packed { kind, .. } => *kind,
            WireBody::OneBit(_) => KIND_ONEBIT,
            WireBody::UniformQ(_) => KIND_UNIFORM_Q,
        }
    }

    /// Support size `k` for masked variants (0 where not applicable —
    /// dense and whole-`d` quantized bodies derive their lane count from
    /// the model dim).
    pub fn k(&self) -> usize {
        match self {
            WireBody::SharedMask { indices, .. } => indices.len(),
            WireBody::SparseTriple { w, .. } => w.nnz(),
            WireBody::SsmQ(msg) => msg.k,
            WireBody::Packed { k, .. } => *k,
            _ => 0,
        }
    }

    /// Quantizer bin count `s − 1` for quantized variants (0 otherwise).
    pub fn levels(&self) -> u32 {
        match self {
            WireBody::SsmQ(msg) => msg.w.levels,
            WireBody::Packed { levels, .. } => *levels,
            WireBody::UniformQ(p) => p.levels,
            _ => 0,
        }
    }

    /// Exact size of the encoded body in bits — the value the ledger
    /// formulae in [`cost`] price.
    pub fn wire_bits(&self) -> u64 {
        match self {
            WireBody::Dense3 { dw, .. } => 3 * dw.len() as u64 * Q,
            WireBody::Dense1 { dw } => dw.len() as u64 * Q,
            WireBody::SharedMask { dim, indices, .. } => {
                mask_bits(*dim, indices.len()).0 + 3 * indices.len() as u64 * Q
            }
            WireBody::SparseTriple { w, .. } => 3 * (mask_bits(w.dim, w.nnz()).0 + w.nnz() as u64 * Q),
            WireBody::SsmQ(msg) => msg.wire_bits(),
            WireBody::Packed {
                kind,
                dim,
                k,
                levels,
                ..
            } => WireBody::expected_bits(*kind, *dim, *k, *levels)
                .expect("fused packed body carries a valid header"),
            WireBody::OneBit(p) => p.wire_bits(),
            WireBody::UniformQ(p) => p.wire_bits(),
        }
    }

    /// Pack the body into one contiguous LSB-first bitstream; the result
    /// is exactly `ceil(wire_bits / 8)` bytes.
    pub fn encode(&self) -> Vec<u8> {
        if let WireBody::Packed { bytes, .. } = self {
            return bytes.clone();
        }
        let mut p = BitPacker::with_capacity(self.wire_bits() as usize);
        match self {
            WireBody::Dense3 { dw, dm, dv } => {
                push_f32s(&mut p, dw);
                push_f32s(&mut p, dm);
                push_f32s(&mut p, dv);
            }
            WireBody::Dense1 { dw } => push_f32s(&mut p, dw),
            WireBody::SharedMask {
                dim,
                indices,
                w,
                m,
                v,
            } => {
                push_positions(&mut p, *dim, indices);
                push_f32s(&mut p, w);
                push_f32s(&mut p, m);
                push_f32s(&mut p, v);
            }
            WireBody::SparseTriple { w, m, v } => {
                for sv in [w, m, v] {
                    push_positions(&mut p, sv.dim, &sv.indices);
                    push_f32s(&mut p, &sv.values);
                }
            }
            WireBody::SsmQ(msg) => {
                // Trusted in-process struct: recover the indices, then
                // repack everything contiguously (the struct's own
                // sections each carry up to 7 padding bits the ledger
                // does not price).
                let indices = decode_positions(msg.encoding, msg.dim, msg.k, &msg.positions);
                push_positions(&mut p, msg.dim, &indices);
                for packet in [&msg.w, &msg.m, &msg.v] {
                    push_codes(&mut p, packet);
                    p.push(packet.scale.to_bits() as u64, Q);
                }
            }
            WireBody::OneBit(packet) => {
                let mut u = BitUnpacker::new(&packet.signs);
                for _ in 0..packet.dim {
                    p.push(u.pull(1), 1);
                }
                p.push(packet.scale.to_bits() as u64, Q);
            }
            WireBody::UniformQ(packet) => {
                let bits = index_bits(packet.levels as usize + 1);
                let mut u = BitUnpacker::new(&packet.codes);
                for _ in 0..packet.dim {
                    p.push(u.pull(bits), bits);
                }
                p.push(packet.scale.to_bits() as u64, Q);
            }
            WireBody::Packed { .. } => unreachable!("returned verbatim above"),
        }
        p.finish()
    }

    /// The priced size implied by the header `(kind, dim, k, levels)` —
    /// what an honest body of this shape must cost.
    pub fn expected_bits(kind: u8, dim: usize, k: usize, levels: u32) -> Result<u64, DecodeError> {
        if k > dim {
            return Err(DecodeError::CountMismatch {
                expected: k,
                got: dim,
            });
        }
        Ok(match kind {
            KIND_DENSE3 => cost::fedadam_dense(dim),
            KIND_DENSE1 => cost::fedsgd_dense(dim),
            KIND_SHARED_MASK => cost::fedadam_ssm(dim, k),
            KIND_SPARSE_TRIPLE => cost::fedadam_top(dim, k),
            KIND_SSM_Q => {
                if levels == 0 {
                    return Err(DecodeError::BadValue("quantizer with zero levels"));
                }
                cost::fedadam_ssm_q(dim, k, levels as usize + 1)
            }
            KIND_ONEBIT => cost::onebit(dim),
            KIND_UNIFORM_Q => {
                if levels == 0 {
                    return Err(DecodeError::BadValue("quantizer with zero levels"));
                }
                cost::uniform(dim, levels as usize + 1)
            }
            _ => return Err(DecodeError::BadValue("unknown wire body kind")),
        })
    }

    /// Decode an **untrusted** body against its header.  Never panics;
    /// accepts only the canonical [`WireBody::encode`] output: the
    /// declared `bits` must match the header-implied size, the byte
    /// length must be exactly `ceil(bits / 8)`, every mask must hold
    /// exactly `k` strictly-increasing positions `< dim`, quantizer codes
    /// must be on-grid, scales finite and non-negative, padding zero.
    pub fn try_decode(
        kind: u8,
        dim: usize,
        k: usize,
        levels: u32,
        bits: u64,
        bytes: &[u8],
    ) -> Result<WireBody, DecodeError> {
        let expected = WireBody::expected_bits(kind, dim, k, levels)?;
        if bits != expected {
            return Err(DecodeError::BadValue("declared bits disagree with header shape"));
        }
        let expected_len = expected.div_ceil(8) as usize;
        if bytes.len() != expected_len {
            return Err(DecodeError::PayloadSize {
                expected: expected_len,
                got: bytes.len(),
            });
        }
        let mut u = BitUnpacker::new(bytes);
        let body = match kind {
            KIND_DENSE3 => WireBody::Dense3 {
                dw: pull_f32s(&mut u, dim)?,
                dm: pull_f32s(&mut u, dim)?,
                dv: pull_f32s(&mut u, dim)?,
            },
            KIND_DENSE1 => WireBody::Dense1 {
                dw: pull_f32s(&mut u, dim)?,
            },
            KIND_SHARED_MASK => {
                let indices = pull_positions(&mut u, dim, k)?;
                WireBody::SharedMask {
                    dim,
                    indices,
                    w: pull_f32s(&mut u, k)?,
                    m: pull_f32s(&mut u, k)?,
                    v: pull_f32s(&mut u, k)?,
                }
            }
            KIND_SPARSE_TRIPLE => {
                let mut svs = Vec::with_capacity(3);
                for _ in 0..3 {
                    let indices = pull_positions(&mut u, dim, k)?;
                    let values = pull_f32s(&mut u, k)?;
                    svs.push(SparseVec {
                        dim,
                        indices,
                        values,
                    });
                }
                let v = svs.pop().expect("three vectors");
                let m = svs.pop().expect("three vectors");
                let w = svs.pop().expect("three vectors");
                WireBody::SparseTriple { w, m, v }
            }
            KIND_SSM_Q => {
                let indices = pull_positions(&mut u, dim, k)?;
                let (encoding, positions) = encode_positions(dim, &indices);
                let mut packets = Vec::with_capacity(3);
                for _ in 0..3 {
                    packets.push(pull_packet(&mut u, k, levels)?);
                }
                let v = packets.pop().expect("three packets");
                let m = packets.pop().expect("three packets");
                let w = packets.pop().expect("three packets");
                WireBody::SsmQ(SsmQUplink {
                    dim,
                    k,
                    encoding,
                    positions,
                    w,
                    m,
                    v,
                })
            }
            KIND_ONEBIT => {
                let mut p = BitPacker::with_capacity(dim);
                for _ in 0..dim {
                    p.push(u.try_pull(1)?, 1);
                }
                let scale = pull_scale(&mut u)?;
                WireBody::OneBit(OneBitPacket {
                    dim,
                    scale,
                    signs: p.finish(),
                })
            }
            KIND_UNIFORM_Q => {
                let bits_per = index_bits(levels as usize + 1);
                let mut p = BitPacker::with_capacity(dim * bits_per as usize);
                for _ in 0..dim {
                    let q = u.try_pull(bits_per)?;
                    if q > levels as u64 {
                        return Err(DecodeError::BadValue("quantizer code above top level"));
                    }
                    p.push(q, bits_per);
                }
                let scale = pull_scale(&mut u)?;
                WireBody::UniformQ(UniformPacket {
                    dim,
                    scale,
                    levels,
                    codes: p.finish(),
                })
            }
            _ => unreachable!("expected_bits rejected unknown kinds"),
        };
        let pad = u.remaining_bits() as u64;
        if pad > 0 && u.try_pull(pad)? != 0 {
            return Err(DecodeError::BadValue("nonzero body padding bits"));
        }
        Ok(body)
    }

    /// Reconstruct the exact [`Upload`] the in-process
    /// [`Algorithm::compress`] would have produced for this message —
    /// dequantization and sparse reconstruction run through the fallible
    /// decoders, so a malformed message errors instead of panicking.
    ///
    /// [`Algorithm::compress`]: super::Algorithm::compress
    pub fn try_into_upload(self, weight: f64) -> Result<Upload, DecodeError> {
        let bits = self.wire_bits();
        let (dw, dm, dv) = match self {
            WireBody::Dense3 { dw, dm, dv } => (
                Recon::Dense(dw),
                Some(Recon::Dense(dm)),
                Some(Recon::Dense(dv)),
            ),
            WireBody::Dense1 { dw } => (Recon::Dense(dw), None, None),
            WireBody::SharedMask {
                dim,
                indices,
                w,
                m,
                v,
            } => {
                let sv = |values: Vec<f32>, indices: Vec<u32>| {
                    Recon::Sparse(SparseVec {
                        dim,
                        indices,
                        values,
                    })
                };
                (
                    sv(w, indices.clone()),
                    Some(sv(m, indices.clone())),
                    Some(sv(v, indices)),
                )
            }
            WireBody::SparseTriple { w, m, v } => (
                Recon::Sparse(w),
                Some(Recon::Sparse(m)),
                Some(Recon::Sparse(v)),
            ),
            WireBody::SsmQ(msg) => {
                let (w, m, v) = try_ssm_q_decode(&msg)?;
                (
                    Recon::Sparse(w),
                    Some(Recon::Sparse(m)),
                    Some(Recon::Sparse(v)),
                )
            }
            WireBody::Packed {
                kind,
                dim,
                k,
                levels,
                bytes,
            } => {
                // A fused pre-encoded body decodes through the same
                // untrusted path a socket peer's bytes would, then
                // converts structurally — one code path for
                // "bytes → upload", no trusted shortcut.
                return WireBody::try_decode(kind, dim, k, levels, bits, &bytes)?
                    .try_into_upload(weight);
            }
            WireBody::OneBit(packet) => (Recon::Dense(try_onebit_decompress(&packet)?), None, None),
            WireBody::UniformQ(packet) => {
                (Recon::Dense(try_uniform_decompress(&packet)?), None, None)
            }
        };
        Ok(Upload {
            dw,
            dm,
            dv,
            weight,
            bits,
        })
    }
}

/// Push the canonical `min{bitmap, index-list}` position coding for
/// `indices` (sorted unique, `< dim`) into the contiguous stream —
/// bit-for-bit the coding [`encode_positions`] produces, minus its byte
/// padding.  Delegates to the shared word-at-a-time packer in
/// [`crate::sparse::codec`] (the same routine the fused device-side
/// encoders write through).
fn push_positions(p: &mut BitPacker, dim: usize, indices: &[u32]) {
    pack_positions(p, dim, indices);
}

/// Pull the canonical position coding back out, validating exactly `k`
/// strictly-increasing indices `< dim`.
fn pull_positions(u: &mut BitUnpacker, dim: usize, k: usize) -> Result<Vec<u32>, DecodeError> {
    let (_, enc) = mask_bits(dim, k);
    match enc {
        MaskEncoding::Bitmap => {
            let mut out = Vec::with_capacity(k.min(dim));
            for i in 0..dim {
                if u.try_pull(1)? == 1 {
                    out.push(i as u32);
                }
            }
            if out.len() != k {
                return Err(DecodeError::CountMismatch {
                    expected: k,
                    got: out.len(),
                });
            }
            Ok(out)
        }
        MaskEncoding::IndexList => {
            let bits = index_bits(dim);
            let mut out = Vec::with_capacity(k);
            let mut prev: Option<u32> = None;
            for _ in 0..k {
                let i = u.try_pull(bits)? as u32;
                if i as usize >= dim {
                    return Err(DecodeError::BadIndex { index: i, dim });
                }
                if let Some(pv) = prev {
                    if i <= pv {
                        return Err(DecodeError::NonIncreasing { prev: pv, next: i });
                    }
                }
                prev = Some(i);
                out.push(i);
            }
            Ok(out)
        }
    }
}

fn push_f32s(p: &mut BitPacker, vals: &[f32]) {
    for &v in vals {
        p.push(v.to_bits() as u64, Q);
    }
}

fn pull_f32s(u: &mut BitUnpacker, n: usize) -> Result<Vec<f32>, DecodeError> {
    let mut out = Vec::with_capacity(n);
    for _ in 0..n {
        out.push(f32::from_bits(u.try_pull(Q)? as u32));
    }
    Ok(out)
}

/// Re-emit a value packet's `k` codes into the contiguous stream
/// (in-process packets pad each code buffer to a byte; the ledger does
/// not price that padding).
fn push_codes(p: &mut BitPacker, packet: &SparseUniformPacket) {
    let bits = index_bits(packet.s_levels() as usize);
    let mut u = BitUnpacker::new(&packet.codes);
    for _ in 0..packet.k {
        p.push(u.pull(bits), bits);
    }
}

/// Pull one value packet (`k` on-grid codes + a finite scale) back out.
fn pull_packet(
    u: &mut BitUnpacker,
    k: usize,
    levels: u32,
) -> Result<SparseUniformPacket, DecodeError> {
    let bits = index_bits(levels as usize + 1);
    let mut p = BitPacker::with_capacity(k * bits as usize);
    for _ in 0..k {
        let q = u.try_pull(bits)?;
        if q > levels as u64 {
            return Err(DecodeError::BadValue("quantizer code above top level"));
        }
        p.push(q, bits);
    }
    let scale = pull_scale(u)?;
    Ok(SparseUniformPacket {
        k,
        scale,
        levels,
        codes: p.finish(),
    })
}

fn pull_scale(u: &mut BitUnpacker) -> Result<f32, DecodeError> {
    let scale = f32::from_bits(u.try_pull(Q)? as u32);
    if !scale.is_finite() || scale < 0.0 {
        return Err(DecodeError::BadValue("non-finite or negative quantizer scale"));
    }
    Ok(scale)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::sparse_uniform::ssm_q_encode;
    use crate::quant::{onebit_compress, uniform_compress, ErrorFeedback};
    use crate::rng::Rng;

    fn roundtrip(body: WireBody) {
        let bits = body.wire_bits();
        let bytes = body.encode();
        assert_eq!(
            bytes.len() as u64,
            bits.div_ceil(8),
            "framed-byte honesty: {:?}",
            body.kind()
        );
        let dim = match &body {
            WireBody::Dense3 { dw, .. } | WireBody::Dense1 { dw } => dw.len(),
            WireBody::SharedMask { dim, .. } => *dim,
            WireBody::SparseTriple { w, .. } => w.dim,
            WireBody::SsmQ(msg) => msg.dim,
            WireBody::Packed { dim, .. } => *dim,
            WireBody::OneBit(p) => p.dim,
            WireBody::UniformQ(p) => p.dim,
        };
        let back =
            WireBody::try_decode(body.kind(), dim, body.k(), body.levels(), bits, &bytes).unwrap();
        // Canonicality: decoding then re-encoding reproduces the bytes.
        assert_eq!(back.encode(), bytes);
        // And the reconstructed uploads agree bit-exactly.
        let a = body.try_into_upload(1.0).unwrap();
        let b = back.try_into_upload(1.0).unwrap();
        assert_eq!(format!("{a:?}"), format!("{b:?}"));
    }

    #[test]
    fn all_kinds_roundtrip_with_exact_byte_honesty() {
        let mut rng = Rng::new(77);
        let d = 100;
        let x: Vec<f32> = (0..d).map(|_| rng.normal() as f32).collect();
        let idx: Vec<u32> = vec![0, 7, 13, 42, 99];
        let vals: Vec<f32> = idx.iter().map(|&i| x[i as usize]).collect();
        roundtrip(WireBody::Dense3 {
            dw: x.clone(),
            dm: x.clone(),
            dv: x.clone(),
        });
        roundtrip(WireBody::Dense1 { dw: x.clone() });
        roundtrip(WireBody::SharedMask {
            dim: d,
            indices: idx.clone(),
            w: vals.clone(),
            m: vals.clone(),
            v: vals.clone(),
        });
        roundtrip(WireBody::SparseTriple {
            w: SparseVec {
                dim: d,
                indices: vec![1, 5, 9, 50, 98],
                values: vals.clone(),
            },
            m: SparseVec {
                dim: d,
                indices: idx.clone(),
                values: vals.clone(),
            },
            v: SparseVec {
                dim: d,
                indices: vec![0, 1, 2, 3, 4],
                values: vals.clone(),
            },
        });
        for s in [2u32, 3, 16] {
            roundtrip(WireBody::SsmQ(ssm_q_encode(d, &idx, &vals, &vals, &vals, s)));
            roundtrip(WireBody::UniformQ(uniform_compress(&x, s)));
        }
        let mut ef = ErrorFeedback::new(d);
        roundtrip(WireBody::OneBit(onebit_compress(&x, &mut ef)));
    }

    #[test]
    fn packed_body_is_transparent() {
        // A fused pre-encoded body must be indistinguishable on the wire
        // from the staged structured body it shortcuts: same header
        // accessors, same bytes, same reconstructed upload.
        let mut rng = Rng::new(78);
        let d = 170;
        let x: Vec<f32> = (0..d).map(|_| rng.normal() as f32).collect();
        let idx: Vec<u32> = vec![0, 8, 13, 42, 169];
        let vals: Vec<f32> = idx.iter().map(|&i| x[i as usize]).collect();
        for s in [2u32, 3, 16] {
            let staged = WireBody::SsmQ(ssm_q_encode(d, &idx, &vals, &vals, &vals, s));
            let fused = crate::quant::sparse_uniform::ssm_q_encode_fused(d, &idx, &x, &x, &x, s);
            let packed = WireBody::Packed {
                kind: KIND_SSM_Q,
                dim: d,
                k: idx.len(),
                levels: s - 1,
                bytes: fused.bytes,
            };
            assert_eq!(packed.kind(), staged.kind());
            assert_eq!(packed.k(), staged.k());
            assert_eq!(packed.levels(), staged.levels());
            assert_eq!(packed.wire_bits(), staged.wire_bits());
            assert_eq!(packed.encode(), staged.encode(), "s={s}");
            let a = packed.try_into_upload(1.0).unwrap();
            let b = staged.try_into_upload(1.0).unwrap();
            assert_eq!(format!("{a:?}"), format!("{b:?}"));
        }
        // The f32 shared-mask family takes the same shortcut.
        let staged = WireBody::SharedMask {
            dim: d,
            indices: idx.clone(),
            w: vals.clone(),
            m: vals.clone(),
            v: vals.clone(),
        };
        let packed = WireBody::Packed {
            kind: KIND_SHARED_MASK,
            dim: d,
            k: idx.len(),
            levels: 0,
            bytes: staged.encode(),
        };
        assert_eq!(packed.wire_bits(), staged.wire_bits());
        assert_eq!(
            format!("{:?}", packed.try_into_upload(1.0).unwrap()),
            format!("{:?}", staged.try_into_upload(1.0).unwrap())
        );
    }

    #[test]
    fn bitmap_masks_also_roundtrip() {
        // Dense-enough support flips the coding to Bitmap (d <= k log d).
        let d = 64usize;
        let indices: Vec<u32> = (0..32).map(|i| i * 2).collect();
        let (_, enc) = mask_bits(d, indices.len());
        assert_eq!(enc, MaskEncoding::Bitmap);
        let vals = vec![1.5f32; 32];
        roundtrip(WireBody::SharedMask {
            dim: d,
            indices,
            w: vals.clone(),
            m: vals.clone(),
            v: vals,
        });
    }

    #[test]
    fn mispriced_send_is_refused_in_every_profile() {
        // The satellite-3 contract: priced-size == framed-size is a hard
        // `Result` at send time, not a debug_assert — this test must pass
        // under `cargo test --release` too.
        let up = WireUpload {
            body: WireBody::Dense1 {
                dw: vec![1.0, 2.0, 3.0],
            },
            weight: 1.0,
            bits: 3 * 32 + 1, // off by one bit vs the honest 3·q
        };
        let err = up.encode_body().unwrap_err();
        assert!(err.to_string().contains("mispriced"), "{err}");
        let honest = WireUpload {
            bits: 3 * 32,
            ..up
        };
        assert_eq!(honest.encode_body().unwrap().len(), 12);
    }

    #[test]
    fn try_decode_rejects_mutations_and_truncations() {
        let body = WireBody::SharedMask {
            dim: 1 << 14,
            indices: vec![5, 100, 9000],
            w: vec![1.0, 2.0, 3.0],
            m: vec![4.0, 5.0, 6.0],
            v: vec![7.0, 8.0, 9.0],
        };
        let bits = body.wire_bits();
        let bytes = body.encode();
        // Truncation at every byte boundary errors.
        for cut in 0..bytes.len() {
            assert!(
                WireBody::try_decode(KIND_SHARED_MASK, 1 << 14, 3, 0, bits, &bytes[..cut]).is_err(),
                "cut={cut}"
            );
        }
        // Dishonest declared bits error.
        assert!(WireBody::try_decode(KIND_SHARED_MASK, 1 << 14, 3, 0, bits + 8, &bytes).is_err());
        // Unknown kind errors.
        assert!(WireBody::try_decode(99, 1 << 14, 3, 0, bits, &bytes).is_err());
        // k > dim errors.
        assert!(WireBody::try_decode(KIND_SHARED_MASK, 2, 3, 0, bits, &bytes).is_err());
    }
}
