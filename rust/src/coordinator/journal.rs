//! The coordinator's event journal: an append-only, length-prefixed,
//! checksummed log of every round-loop state transition, plus periodic
//! full-state snapshots — the persistence layer behind
//! [`Coordinator::resume`](super::Coordinator::resume).
//!
//! ## On-disk layout (`<dir>/`)
//!
//! - `journal.log` — the event log.  Each record is framed as
//!   `[len: u32 le][crc32: u32 le][payload: len bytes]`; the payload is
//!   one [`Event`] encoded by the [`crate::util::bytes`] codec.  Record 0
//!   is always [`Event::RunStarted`] carrying the journal format version
//!   and the config fingerprint
//!   ([`ExperimentConfig::fingerprint`](crate::config::ExperimentConfig::fingerprint)),
//!   so a resume can reject a foreign or incompatible journal up front.
//! - `snapshot_<round>.bin` — a full coordinator state snapshot taken
//!   after round `round - 1` completed (i.e. `round` is the next round to
//!   run), framed as `[magic: u32][version: u32][crc32: u32][payload]`.
//!   A snapshot only *counts* once its [`Event::SnapshotWritten`] record
//!   landed in the log — a crash between the file write and the event
//!   append falls back to the previous snapshot.
//!
//! ## Torn-tail tolerance
//!
//! A crash can leave a partial final record.  [`read_log`] stops at the
//! first record whose header is truncated, whose payload is short, or
//! whose CRC-32 mismatches, returning everything before it plus the byte
//! offset of the last valid record end; [`Journal::open_resume`]
//! truncates the file there so subsequent appends continue from a clean
//! prefix.  Nothing before the torn record is ever lost.
//!
//! ## Replay verification
//!
//! Resume does not *apply* logged events — re-execution from the last
//! snapshot regenerates all state deterministically.  Instead the logged
//! tail becomes an oracle: [`Journal::set_replay`] arms the journal with
//! the tail's encoded payloads, and each [`Journal::record`] during
//! re-execution must byte-match the next logged record (nothing is
//! re-written to disk while replaying).  Any mismatch is a determinism
//! violation and fails the resume loudly rather than silently forking
//! history.  Once the tail is exhausted, `record` switches back to
//! appending.

use std::collections::VecDeque;
use std::fs::{File, OpenOptions};
use std::io::Write;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::util::bytes::{crc32, ByteReader, ByteWriter};

/// Journal format version — bumped on any event/snapshot schema change.
/// v2: snapshots serialize per-device residual/moment state as *touched
/// entries only* (id-keyed, via `ResidualStore::save_state`) instead of a
/// dense fleet-sized array, and log rows carry the
/// `fleet_devices`/`cohort_devices` columns.
/// v3: snapshot log rows carry the two measured uplink-latency f64s
/// (`meas_uplink_max_secs`/`meas_uplink_mean_secs`); pure observability,
/// but the row layout changed, so old snapshots must not be trusted.
pub const JOURNAL_VERSION: u32 = 3;
/// Snapshot file magic (`"FJS1"`).
pub const SNAPSHOT_MAGIC: u32 = 0x464A_5331;
/// Event-log file name inside the journal directory.
pub const LOG_FILE: &str = "journal.log";

/// One typed round-loop transition.  Every floating-point field is
/// stored as raw bits (`to_bits`) so event equality — the replay
/// oracle's byte comparison — is exact, NaN included.  `wall_secs` is
/// deliberately absent everywhere: host time is the one non-deterministic
/// column and is excluded from the replay contract.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Event {
    /// Record 0 of every journal: format version + config fingerprint.
    RunStarted { version: u32, fingerprint: u64 },
    /// `WaitingForCohort → Training`: the sampler picked this round's
    /// participants (ids ascending; weights as f64 bits, slot-aligned).
    CohortSelected {
        round: u64,
        devices: Vec<u64>,
        weights: Vec<u64>,
    },
    /// `Training/Aggregating → Applying`: every upload folded.  `folded`
    /// / `expected` surface the accumulator's progress counters;
    /// `uplink_bits` is the ledger's cumulative uplink after this round's
    /// uploads.
    Aggregated {
        round: u64,
        folded: u64,
        expected: u64,
        uplink_bits: u64,
    },
    /// `Applying → Evaluating`: post-processed aggregate applied to the
    /// global state (`update_norm` = ‖ΔŴ‖₂ bits; `downlink_bits`
    /// cumulative).
    Applied {
        round: u64,
        update_norm: u64,
        downlink_bits: u64,
    },
    /// `Evaluating → RoundDone`, inline schedule: eval ran synchronously.
    EvalInline {
        round: u64,
        test_loss: u64,
        test_accuracy: u64,
    },
    /// `Evaluating → RoundDone`, overlapped schedule: eval launched; its
    /// result arrives later as [`Event::EvalReaped`].
    EvalLaunched { round: u64 },
    /// `Evaluating → RoundDone`: not an eval-due round.
    EvalSkipped { round: u64 },
    /// An overlapped eval joined and its log row was patched (emitted at
    /// the deterministic reap point, not at thread completion).
    EvalReaped {
        round: u64,
        test_loss: u64,
        test_accuracy: u64,
    },
    /// `RoundDone → WaitingForCohort`: the round's record was logged
    /// (`train_loss`/`sim_secs` as bits; `wall_secs` excluded by design).
    RoundDone {
        round: u64,
        train_loss: u64,
        sim_secs: u64,
    },
    /// `snapshot_<round>.bin` was fully written and is valid to resume
    /// from (`round` = the next round to run).
    SnapshotWritten { round: u64 },
}

impl Event {
    /// Encode to the journal payload format (framing is the caller's job).
    pub fn encode(&self) -> Vec<u8> {
        let mut w = ByteWriter::new();
        match self {
            Event::RunStarted { version, fingerprint } => {
                w.put_u8(1);
                w.put_u32(*version);
                w.put_u64(*fingerprint);
            }
            Event::CohortSelected { round, devices, weights } => {
                w.put_u8(2);
                w.put_u64(*round);
                w.put_u64s(devices);
                w.put_u64s(weights);
            }
            Event::Aggregated { round, folded, expected, uplink_bits } => {
                w.put_u8(3);
                w.put_u64(*round);
                w.put_u64(*folded);
                w.put_u64(*expected);
                w.put_u64(*uplink_bits);
            }
            Event::Applied { round, update_norm, downlink_bits } => {
                w.put_u8(4);
                w.put_u64(*round);
                w.put_u64(*update_norm);
                w.put_u64(*downlink_bits);
            }
            Event::EvalInline { round, test_loss, test_accuracy } => {
                w.put_u8(5);
                w.put_u64(*round);
                w.put_u64(*test_loss);
                w.put_u64(*test_accuracy);
            }
            Event::EvalLaunched { round } => {
                w.put_u8(6);
                w.put_u64(*round);
            }
            Event::EvalSkipped { round } => {
                w.put_u8(7);
                w.put_u64(*round);
            }
            Event::EvalReaped { round, test_loss, test_accuracy } => {
                w.put_u8(8);
                w.put_u64(*round);
                w.put_u64(*test_loss);
                w.put_u64(*test_accuracy);
            }
            Event::RoundDone { round, train_loss, sim_secs } => {
                w.put_u8(9);
                w.put_u64(*round);
                w.put_u64(*train_loss);
                w.put_u64(*sim_secs);
            }
            Event::SnapshotWritten { round } => {
                w.put_u8(10);
                w.put_u64(*round);
            }
        }
        w.into_inner()
    }

    /// Decode one payload (inverse of [`Event::encode`]; rejects trailing
    /// bytes).
    pub fn decode(payload: &[u8]) -> Result<Event> {
        let mut r = ByteReader::new(payload);
        let tag = r.take_u8()?;
        let ev = match tag {
            1 => Event::RunStarted {
                version: r.take_u32()?,
                fingerprint: r.take_u64()?,
            },
            2 => Event::CohortSelected {
                round: r.take_u64()?,
                devices: r.take_u64s()?,
                weights: r.take_u64s()?,
            },
            3 => Event::Aggregated {
                round: r.take_u64()?,
                folded: r.take_u64()?,
                expected: r.take_u64()?,
                uplink_bits: r.take_u64()?,
            },
            4 => Event::Applied {
                round: r.take_u64()?,
                update_norm: r.take_u64()?,
                downlink_bits: r.take_u64()?,
            },
            5 => Event::EvalInline {
                round: r.take_u64()?,
                test_loss: r.take_u64()?,
                test_accuracy: r.take_u64()?,
            },
            6 => Event::EvalLaunched { round: r.take_u64()? },
            7 => Event::EvalSkipped { round: r.take_u64()? },
            8 => Event::EvalReaped {
                round: r.take_u64()?,
                test_loss: r.take_u64()?,
                test_accuracy: r.take_u64()?,
            },
            9 => Event::RoundDone {
                round: r.take_u64()?,
                train_loss: r.take_u64()?,
                sim_secs: r.take_u64()?,
            },
            10 => Event::SnapshotWritten { round: r.take_u64()? },
            other => bail!("unknown journal event tag {other}"),
        };
        r.finish()?;
        Ok(ev)
    }
}

/// Path of the event log inside a journal directory.
pub fn log_path(dir: &Path) -> PathBuf {
    dir.join(LOG_FILE)
}

/// Path of the snapshot taken with `round` as the next round to run.
pub fn snapshot_path(dir: &Path, round: u64) -> PathBuf {
    dir.join(format!("snapshot_{round}.bin"))
}

/// Everything [`read_log`] recovered from a journal's event log.
pub struct LogContents {
    /// Decoded events, in append order.
    pub events: Vec<Event>,
    /// The exact encoded payload of each event (the replay oracle
    /// compares against these bytes, not a re-decode).
    pub payloads: Vec<Vec<u8>>,
    /// Byte offset of the end of the last valid record — anything past
    /// it is a torn tail to truncate before appending.
    pub valid_len: u64,
}

/// Read a journal's event log, dropping a torn final record (truncated
/// frame, short payload, or CRC mismatch) — see the module docs.
pub fn read_log(dir: &Path) -> Result<LogContents> {
    let path = log_path(dir);
    let bytes = std::fs::read(&path).with_context(|| format!("reading {}", path.display()))?;
    let mut events = Vec::new();
    let mut payloads = Vec::new();
    let mut pos = 0usize;
    while bytes.len() - pos >= 8 {
        let len = u32::from_le_bytes(bytes[pos..pos + 4].try_into().unwrap()) as usize;
        let crc = u32::from_le_bytes(bytes[pos + 4..pos + 8].try_into().unwrap());
        if bytes.len() - pos - 8 < len {
            break; // torn: payload shorter than the frame promises
        }
        let payload = &bytes[pos + 8..pos + 8 + len];
        if crc32(payload) != crc {
            break; // torn or corrupt: checksum mismatch
        }
        // A payload that frames+checksums but does not decode is schema
        // corruption, not a torn tail — fail loudly.
        events.push(Event::decode(payload).with_context(|| {
            format!("decoding journal record {} at byte {pos}", events.len())
        })?);
        payloads.push(payload.to_vec());
        pos += 8 + len;
    }
    Ok(LogContents {
        events,
        payloads,
        valid_len: pos as u64,
    })
}

/// Check that `dir` holds a journal this config can resume
/// (`config::validate` calls this for the `resume` knob): the log exists,
/// record 0 is a [`Event::RunStarted`] with the current
/// [`JOURNAL_VERSION`], and the fingerprint matches.
pub fn verify_resumable(dir: &Path, fingerprint: u64) -> Result<()> {
    if !log_path(dir).is_file() {
        bail!("no event log at {}", log_path(dir).display());
    }
    let contents = read_log(dir)?;
    match contents.events.first() {
        Some(Event::RunStarted { version, fingerprint: fp }) => {
            if *version != JOURNAL_VERSION {
                bail!(
                    "journal format version {version} != supported {JOURNAL_VERSION}"
                );
            }
            if *fp != fingerprint {
                bail!(
                    "foreign journal: its config fingerprint {fp:#018x} does not match \
                     this config's {fingerprint:#018x} (a determinism-bearing knob differs)"
                );
            }
            Ok(())
        }
        Some(other) => bail!("journal record 0 is {other:?}, expected RunStarted"),
        None => bail!("journal at {} has no valid records", dir.display()),
    }
}

/// An open journal: appends framed records, or verifies them against a
/// logged tail while a resume replays.
pub struct Journal {
    file: File,
    dir: PathBuf,
    /// Encoded payloads still expected during replay (front = next).
    replay: VecDeque<Vec<u8>>,
    /// How many events this journal has observed (logged + verified).
    position: usize,
}

impl Journal {
    /// Start a fresh journal in `dir` (created if missing; an existing
    /// log is truncated — a fresh run owns its directory) and append the
    /// [`Event::RunStarted`] header.
    pub fn create(dir: &Path, fingerprint: u64) -> Result<Journal> {
        std::fs::create_dir_all(dir)
            .with_context(|| format!("creating journal dir {}", dir.display()))?;
        let file = File::create(log_path(dir))
            .with_context(|| format!("creating {}", log_path(dir).display()))?;
        let mut j = Journal {
            file,
            dir: dir.to_path_buf(),
            replay: VecDeque::new(),
            position: 0,
        };
        j.record(&Event::RunStarted {
            version: JOURNAL_VERSION,
            fingerprint,
        })?;
        Ok(j)
    }

    /// Open an existing journal for resume: verify the header, read every
    /// valid record, truncate a torn tail, and return the journal (append
    /// handle positioned past the last valid record) plus the recovered
    /// contents.  The replay oracle starts empty — arm it with
    /// [`Journal::set_replay`] once the resume point is chosen.
    pub fn open_resume(dir: &Path, fingerprint: u64) -> Result<(Journal, LogContents)> {
        verify_resumable(dir, fingerprint)?;
        let contents = read_log(dir)?;
        let file = OpenOptions::new()
            .write(true)
            .open(log_path(dir))
            .with_context(|| format!("opening {} for append", log_path(dir).display()))?;
        // Drop the torn tail (no-op when the log ended cleanly) so new
        // records continue from a checksummed prefix.
        file.set_len(contents.valid_len)?;
        let mut j = Journal {
            file,
            dir: dir.to_path_buf(),
            replay: VecDeque::new(),
            position: contents.events.len(),
        };
        use std::io::Seek;
        j.file.seek(std::io::SeekFrom::End(0))?;
        Ok((j, contents))
    }

    /// The journal directory.
    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// Arm the replay oracle with the logged tail's encoded payloads.
    pub fn set_replay(&mut self, payloads: Vec<Vec<u8>>) {
        self.position -= payloads.len();
        self.replay = payloads.into();
    }

    /// `true` while logged tail records remain to verify.
    pub fn replaying(&self) -> bool {
        !self.replay.is_empty()
    }

    /// Observe one event: while replaying, byte-verify it against the
    /// logged tail (a mismatch is a determinism violation and errors);
    /// otherwise frame and append it to disk.
    pub fn record(&mut self, event: &Event) -> Result<()> {
        let payload = event.encode();
        if let Some(expected) = self.replay.pop_front() {
            if expected != payload {
                let logged = Event::decode(&expected)
                    .map(|e| format!("{e:?}"))
                    .unwrap_or_else(|_| "<undecodable>".into());
                bail!(
                    "journal replay diverged at record {}: re-execution produced {event:?} \
                     but the log holds {logged} — the resumed run is not reproducing the \
                     original (determinism violation)",
                    self.position
                );
            }
            self.position += 1;
            return Ok(());
        }
        let mut frame = Vec::with_capacity(8 + payload.len());
        frame.extend_from_slice(&(payload.len() as u32).to_le_bytes());
        frame.extend_from_slice(&crc32(&payload).to_le_bytes());
        frame.extend_from_slice(&payload);
        self.file
            .write_all(&frame)
            .with_context(|| format!("appending to {}", log_path(&self.dir).display()))?;
        self.position += 1;
        Ok(())
    }

    /// Write `snapshot_<round>.bin` (magic + version + CRC framing around
    /// `payload`).  The caller must follow up with a
    /// [`Event::SnapshotWritten`] record — only that makes it resumable.
    pub fn write_snapshot(&self, round: u64, payload: &[u8]) -> Result<()> {
        let mut framed = Vec::with_capacity(12 + payload.len());
        framed.extend_from_slice(&SNAPSHOT_MAGIC.to_le_bytes());
        framed.extend_from_slice(&JOURNAL_VERSION.to_le_bytes());
        framed.extend_from_slice(&crc32(payload).to_le_bytes());
        framed.extend_from_slice(payload);
        let path = snapshot_path(&self.dir, round);
        std::fs::write(&path, framed).with_context(|| format!("writing {}", path.display()))
    }
}

/// Read and validate a snapshot file, returning its payload.
pub fn read_snapshot(path: &Path) -> Result<Vec<u8>> {
    let bytes = std::fs::read(path).with_context(|| format!("reading {}", path.display()))?;
    if bytes.len() < 12 {
        bail!("snapshot {} is truncated ({} bytes)", path.display(), bytes.len());
    }
    let magic = u32::from_le_bytes(bytes[0..4].try_into().unwrap());
    let version = u32::from_le_bytes(bytes[4..8].try_into().unwrap());
    let crc = u32::from_le_bytes(bytes[8..12].try_into().unwrap());
    if magic != SNAPSHOT_MAGIC {
        bail!("snapshot {} has bad magic {magic:#010x}", path.display());
    }
    if version != JOURNAL_VERSION {
        bail!(
            "snapshot {} has format version {version} != supported {JOURNAL_VERSION}",
            path.display()
        );
    }
    let payload = &bytes[12..];
    if crc32(payload) != crc {
        bail!("snapshot {} fails its checksum", path.display());
    }
    Ok(payload.to_vec())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp_dir(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("fedadam-journal-{}-{tag}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        dir
    }

    fn sample_events() -> Vec<Event> {
        vec![
            Event::CohortSelected {
                round: 0,
                devices: vec![0, 2],
                weights: vec![64.0f64.to_bits(), 32.0f64.to_bits()],
            },
            Event::Aggregated {
                round: 0,
                folded: 2,
                expected: 2,
                uplink_bits: 12_345,
            },
            Event::Applied {
                round: 0,
                update_norm: 0.5f64.to_bits(),
                downlink_bits: 777,
            },
            Event::EvalInline {
                round: 0,
                test_loss: 2.3f64.to_bits(),
                test_accuracy: 0.1f64.to_bits(),
            },
            Event::RoundDone {
                round: 0,
                train_loss: 1.25f64.to_bits(),
                sim_secs: f64::NAN.to_bits(),
            },
            Event::SnapshotWritten { round: 1 },
            Event::EvalLaunched { round: 1 },
            Event::EvalSkipped { round: 2 },
            Event::EvalReaped {
                round: 1,
                test_loss: 2.2f64.to_bits(),
                test_accuracy: 0.2f64.to_bits(),
            },
        ]
    }

    #[test]
    fn events_roundtrip_through_the_codec() {
        for ev in sample_events() {
            let decoded = Event::decode(&ev.encode()).unwrap();
            assert_eq!(decoded, ev);
        }
        assert!(Event::decode(&[99]).is_err(), "unknown tag must error");
        assert!(Event::decode(&[]).is_err(), "empty payload must error");
        // Trailing garbage after a valid event must be rejected.
        let mut bytes = Event::EvalLaunched { round: 3 }.encode();
        bytes.push(0);
        assert!(Event::decode(&bytes).is_err());
    }

    #[test]
    fn journal_appends_and_reads_back() {
        let dir = tmp_dir("append");
        let mut j = Journal::create(&dir, 0xABCD).unwrap();
        for ev in sample_events() {
            j.record(&ev).unwrap();
        }
        drop(j);
        let contents = read_log(&dir).unwrap();
        assert_eq!(
            contents.events[0],
            Event::RunStarted {
                version: JOURNAL_VERSION,
                fingerprint: 0xABCD
            }
        );
        assert_eq!(&contents.events[1..], sample_events().as_slice());
        verify_resumable(&dir, 0xABCD).unwrap();
        let err = verify_resumable(&dir, 0xEF01).unwrap_err().to_string();
        assert!(err.contains("foreign journal"), "{err}");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn torn_tail_is_dropped_and_truncated_on_resume() {
        let dir = tmp_dir("torn");
        let mut j = Journal::create(&dir, 7).unwrap();
        for ev in sample_events() {
            j.record(&ev).unwrap();
        }
        drop(j);
        let clean = read_log(&dir).unwrap();
        // Tear the final record: chop 3 bytes off the file.
        let path = log_path(&dir);
        let bytes = std::fs::read(&path).unwrap();
        std::fs::write(&path, &bytes[..bytes.len() - 3]).unwrap();
        let torn = read_log(&dir).unwrap();
        assert_eq!(torn.events.len(), clean.events.len() - 1);
        assert_eq!(torn.events, clean.events[..clean.events.len() - 1]);
        // Resume truncates the tail and can append cleanly again.
        let (mut j, contents) = Journal::open_resume(&dir, 7).unwrap();
        assert_eq!(contents.events.len(), torn.events.len());
        j.record(&Event::EvalSkipped { round: 9 }).unwrap();
        drop(j);
        let again = read_log(&dir).unwrap();
        assert_eq!(again.events.last(), Some(&Event::EvalSkipped { round: 9 }));
        assert_eq!(again.events.len(), torn.events.len() + 1);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn corrupted_payload_fails_its_checksum() {
        let dir = tmp_dir("crc");
        let mut j = Journal::create(&dir, 1).unwrap();
        j.record(&Event::EvalLaunched { round: 5 }).unwrap();
        drop(j);
        let path = log_path(&dir);
        let mut bytes = std::fs::read(&path).unwrap();
        let last = bytes.len() - 1;
        bytes[last] ^= 0xFF; // flip a payload byte of the final record
        std::fs::write(&path, &bytes).unwrap();
        let contents = read_log(&dir).unwrap();
        // The corrupted final record is dropped; the header survives.
        assert_eq!(contents.events.len(), 1);
        assert!(matches!(contents.events[0], Event::RunStarted { .. }));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn replay_oracle_verifies_and_rejects_divergence() {
        let dir = tmp_dir("replay");
        let mut j = Journal::create(&dir, 2).unwrap();
        let evs = sample_events();
        for ev in &evs {
            j.record(ev).unwrap();
        }
        drop(j);
        let (mut j, contents) = Journal::open_resume(&dir, 2).unwrap();
        j.set_replay(contents.payloads[1..].to_vec());
        assert!(j.replaying());
        for ev in &evs {
            j.record(ev).unwrap();
        }
        assert!(!j.replaying());
        // Past the tail, appends go to disk again.
        j.record(&Event::EvalSkipped { round: 42 }).unwrap();
        drop(j);
        assert_eq!(read_log(&dir).unwrap().events.len(), evs.len() + 2);
        // A diverging event must error, not silently fork history.
        let (mut j, contents) = Journal::open_resume(&dir, 2).unwrap();
        j.set_replay(contents.payloads[1..].to_vec());
        let err = j
            .record(&Event::EvalSkipped { round: 1234 })
            .unwrap_err()
            .to_string();
        assert!(err.contains("determinism violation"), "{err}");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn snapshot_files_roundtrip_and_reject_corruption() {
        let dir = tmp_dir("snap");
        let j = Journal::create(&dir, 3).unwrap();
        let payload: Vec<u8> = (0..=255).collect();
        j.write_snapshot(4, &payload).unwrap();
        assert_eq!(read_snapshot(&snapshot_path(&dir, 4)).unwrap(), payload);
        let mut bytes = std::fs::read(snapshot_path(&dir, 4)).unwrap();
        bytes[20] ^= 1;
        std::fs::write(snapshot_path(&dir, 4), &bytes).unwrap();
        assert!(read_snapshot(&snapshot_path(&dir, 4)).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }
}
