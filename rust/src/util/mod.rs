//! Small self-contained substrates (the offline build has no serde):
//! a JSON parser for the AOT manifest and a TOML-subset parser for
//! experiment configs.

pub mod json;
pub mod toml;
